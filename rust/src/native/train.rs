//! Host-side DSG TRAINING engine — paper Algorithm 1 without XLA.
//!
//! The HLO `Trainer` needs PJRT artifacts that this environment cannot
//! build, so training never ran in CI.  This module owns the whole train
//! step natively: a taped forward over the exported topology (dense /
//! conv via im2col / residual / BN / relu / maxpool / classifier), then
//! a reverse walk that backpropagates THROUGH the DSG masks.
//!
//! The paper's training claim is implemented structurally: the DRS
//! `RowMask` selected in the forward is applied to both the activations
//! (masked VMM computes only selected neurons) and their gradients — the
//! backward kernels (`sparse::parallel::vmm_rowmask_backward_chunk` /
//! `vmm_rowmask_gradw_chunk`) iterate ONLY the selected indices, so
//! unselected gradient entries are never read and never contribute to
//! dX or dW (Algorithm 1's forced gradient sparsification).  The DMS
//! double mask keeps BN consistent: mask 2's zeros are re-applied to the
//! upstream gradient before the BN backward, exactly mirroring the
//! forward's `out = BN(s) * mask`.
//!
//! BatchNorm runs in TRAINING mode (batch statistics, biased variance,
//! 0.9 running-average update) with the standard full backward (mean and
//! variance are functions of the input).  Updates are SGD + momentum
//! (`v <- 0.9 v - lr g; w <- w + v`), applied leaf-wise to params and BN
//! affines with their velocity twins, mirroring `python/compile/train.py`.
//!
//! Numerics: per-element accumulation in the matmul/VMM kernels is the
//! same row-split code the inference engine uses, so results are
//! bit-exact for any thread budget; column reductions (BN stats, BN
//! backward sums, bias grads) accumulate in f64.  `Mode::Dense` runs the
//! identical kernels under a keep-all mask, which is what makes the
//! gamma = 0 DSG step bit-identical to the dense baseline.
//!
//! TAPE STORAGE (§3.3, Fig 6): the paper's training-memory claim is that
//! stashed activations dominate the footprint and that ZVC compression
//! recovers most of it.  [`TapeStorage::Zvc`] makes that real here:
//! every taped activation that is sparse (post-ReLU / post-double-mask)
//! is stored as a [`crate::zvc::Compressed`] record and decompressed on
//! demand into a scratch buffer reused across the backward walk.  ZVC is
//! lossless, so compressed-tape training is BIT-IDENTICAL to dense-tape
//! training (asserted in `tests/native_train.rs`), and the
//! [`crate::metrics::MemoryMeter`] records measured live/peak tape bytes
//! per record so the Fig 6 saving is a number we measure, not just model.

use crate::coordinator::ModelState;
use crate::drs::projection::TernaryIndex;
use crate::drs::topk::{RowMask, SelectionMode};
use crate::metrics::{MemoryMeter, OpsCounter, TapeAlloc};
use crate::native::{to_tensor, Carry, Mode, NativeModel};
use crate::runtime::{Meta, Unit};
use crate::sparse::parallel::{self, NzIndex, SparseKernels};
use crate::sparse::simd;
use crate::tensor::ops;
use crate::util::faults;
use crate::zvc;
use anyhow::{bail, ensure, Context, Result};
use std::collections::BTreeMap;

/// SGD momentum (mirrors `train.py::MOMENTUM`).
pub const MOMENTUM: f32 = 0.9;
/// BN running-average momentum (mirrors `layers.py::BN_MOMENTUM`).
pub const BN_MOMENTUM: f32 = 0.9;
const BN_EPS: f32 = 1e-5;

/// One training step's scalar results (the native twin of
/// [`crate::coordinator::StepOut`]).
#[derive(Clone, Debug)]
pub struct TrainOut {
    pub loss: f32,
    pub acc: f32,
    /// measured mask density per DSG layer, in dsg order
    pub densities: Vec<f32>,
}

/// Named gradients collected during a backward walk, in encounter order
/// (reverse unit order; within a rows layer: bn.scale, bn.bias, weight).
/// The backward COLLECTS instead of applying so the same walk serves
/// both the sequential step (apply after the walk — bit-identical to
/// the old inline applies, since every unit's backward reads only its
/// own pre-update leaves) and the data-parallel leaf step (pure
/// gradients, no `&mut ModelState` anywhere near worker threads).
#[derive(Default)]
pub(crate) struct GradStore {
    grads: Vec<(String, Vec<f32>)>,
}

impl GradStore {
    fn push(&mut self, name: String, g: Vec<f32>) {
        self.grads.push((name, g));
    }

    /// The collected (name, gradient) list, in apply order.
    pub(crate) fn take(self) -> Vec<(String, Vec<f32>)> {
        self.grads
    }
}

/// One BN layer's leaf-local batch statistics, weighted by the row
/// count they were computed over (`rows` = the layer's m: examples for
/// dense layers, examples x spatial positions for convs).
#[derive(Clone, Debug)]
pub(crate) struct BnStat {
    pub path: String,
    pub rows: u64,
    pub mean: Vec<f32>,
    pub var: Vec<f32>,
}

/// Everything one data-parallel leaf contributes to the global step:
/// pure sums/gradients only — the caller owns all state mutation.
#[derive(Clone, Debug, Default)]
pub(crate) struct LeafOut {
    /// examples in this leaf
    pub rows: u32,
    /// summed (NOT averaged) cross-entropy over the leaf's examples
    pub loss_sum: f64,
    pub correct: u32,
    /// per DSG layer, in dsg order: (selected, total) mask entries
    pub densities: Vec<(u64, u64)>,
    /// leaf-local BN batch stats per BN layer, in unit order
    pub bn: Vec<BnStat>,
    /// named gradients in apply order, scaled by the GLOBAL batch size
    pub grads: Vec<(String, Vec<f32>)>,
}

/// How the training tape stores activations (§3.3): raw f32 buffers or
/// ZVC-compressed records with on-demand decompression in the backward
/// pass.  ZVC is lossless, so the two are bit-identical; `Zvc` trades
/// one compress + one decompress sweep per taped activation for the
/// Fig 6 memory saving.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum TapeStorage {
    /// Tape raw f32 buffers (the baseline the paper compares against).
    #[default]
    Dense,
    /// ZVC-compress sparse (post-ReLU / post-mask) activations.
    Zvc,
}

impl TapeStorage {
    pub fn parse(s: &str) -> Option<TapeStorage> {
        match s {
            "dense" => Some(TapeStorage::Dense),
            "zvc" => Some(TapeStorage::Zvc),
            _ => None,
        }
    }
}

/// A taped activation: raw, or ZVC-compressed when the tape runs in
/// [`TapeStorage::Zvc`] and the encoding actually wins.  The raw
/// variant carries the nnz count when the store path already computed
/// it, so the meter never re-scans what was scanned once.
pub(crate) enum TapedAct {
    Dense(Vec<f32>, Option<usize>),
    Zvc(zvc::Compressed),
}

impl TapedAct {
    /// The ONE store decision, shared by [`TapedAct::store`] and
    /// [`TapedAct::store_ref`]: in Zvc mode, compress where the encoding
    /// wins (post-ReLU / post-mask activations) — the codec's own
    /// bitmask + count pass doubles as the decision, so no separate nnz
    /// pre-scan runs; a dense tensor (the input image, a GAP output)
    /// stays raw, with the measured count kept for the meter.  In Dense
    /// mode nothing is scanned (`Err(None)` = "unmeasured").
    /// `bm` is the active kernel table's bitmask primitive — every table
    /// entry produces byte-identical masks/counts, so the tape encoding
    /// never depends on the kernel mode.
    fn try_zvc(
        xs: &[f32],
        storage: TapeStorage,
        threads: usize,
        bm: simd::BitmaskCountFn,
    ) -> Result<zvc::Compressed, Option<usize>> {
        if storage != TapeStorage::Zvc {
            return Err(None);
        }
        let mut c = zvc::Compressed::new();
        match zvc::compress_parallel_into_if_smaller_bm(xs, threads, bm, &mut c) {
            Ok(_) => Ok(c),
            Err(nnz) => Err(Some(nnz)),
        }
    }

    /// Tape an owned buffer under `storage`.  Lossless either way: the
    /// backward sees identical bits.
    fn store(
        xs: Vec<f32>,
        storage: TapeStorage,
        threads: usize,
        bm: simd::BitmaskCountFn,
    ) -> TapedAct {
        match Self::try_zvc(&xs, storage, threads, bm) {
            Ok(c) => TapedAct::Zvc(c),
            Err(nnz) => TapedAct::Dense(xs, nnz),
        }
    }

    /// [`TapedAct::store`] from a borrowed slice: in Zvc mode the codec
    /// reads straight from the forward buffer (no transient dense clone
    /// — the clone would be a real, unmetered memory peak); only a
    /// raw-stored record copies.
    fn store_ref(
        xs: &[f32],
        storage: TapeStorage,
        threads: usize,
        bm: simd::BitmaskCountFn,
    ) -> TapedAct {
        match Self::try_zvc(xs, storage, threads, bm) {
            Ok(c) => TapedAct::Zvc(c),
            Err(nnz) => TapedAct::Dense(xs.to_vec(), nnz),
        }
    }

    fn len(&self) -> usize {
        match self {
            TapedAct::Dense(v, _) => v.len(),
            TapedAct::Zvc(c) => c.n,
        }
    }

    /// Non-zero count where it is already known — compressed records
    /// and Zvc-mode raw records (cached from the store decision).
    /// `None` for dense-tape records: measuring them would cost the
    /// very scan the dense baseline is supposed to be free of.
    fn nnz_hint(&self) -> Option<usize> {
        match self {
            TapedAct::Dense(_, cached) => *cached,
            TapedAct::Zvc(c) => Some(c.nnz()),
        }
    }

    fn dense_nbytes(&self) -> usize {
        4 * self.len()
    }

    /// Bytes this record actually holds on the tape.
    fn stored_nbytes(&self) -> usize {
        match self {
            TapedAct::Dense(v, _) => 4 * v.len(),
            TapedAct::Zvc(c) => c.nbytes(),
        }
    }

    /// View densely, decompressing into `scratch` when compressed (the
    /// scratch is reused across units in the backward walk).
    fn slice<'a>(&'a self, scratch: &'a mut Vec<f32>) -> &'a [f32] {
        match self {
            TapedAct::Dense(v, _) => v,
            TapedAct::Zvc(c) => {
                zvc::decompress_into(c, scratch);
                scratch
            }
        }
    }
}

/// Decompression scratch for the backward walk: one buffer for the
/// unit-input activation, one for the post-relu tape (both can be live
/// at once inside a layer backward).  Reused across units and steps.
#[derive(Default)]
struct TapeDecode {
    x: Vec<f32>,
    s: Vec<f32>,
}

/// Static shape of one conv application.
#[derive(Clone, Copy, Debug)]
struct ConvShape {
    ksize: usize,
    stride: usize,
    pad: usize,
}

/// Reusable forward/backward scratch.  The tape owns per-layer records
/// (they must survive until the backward walk); these buffers are the
/// ones safely reusable across layers within one pass.
#[derive(Default)]
struct Scratch {
    /// transposed dense/classifier weights (n, d)
    wt: Vec<f32>,
    /// transposed-layout weight gradient (n, d)
    gwt: Vec<f32>,
    /// im2col rows of the current conv input
    rows: Vec<f32>,
    /// rows-layout upstream gradient (conv backward)
    dyr: Vec<f32>,
    /// nonzero-coordinate index of the current layer input (shared by
    /// every gradW chunk — one gather pass per layer, reused storage)
    nzx: NzIndex,
    drs: DrsScratch,
}

/// DRS-side scratch (projection, virtual activations, threshold pool).
#[derive(Default)]
struct DrsScratch {
    xp: Vec<f32>,
    virt: Vec<f32>,
    thr: Vec<f32>,
    /// (score, index) pairs for structured per-row top-k selection
    pairs: Vec<(f32, u32)>,
}

/// Per-matmul-layer tape record (rows layout).
struct RowsTape {
    m: usize,
    d: usize,
    n: usize,
    w_name: String,
    /// BN leaf path ("3" / "5.bn1"); None when the model runs without BN
    bn_path: Option<String>,
    /// post-relu, pre-BN activations (m, n) — relu' and BN backward
    /// input; ZVC-compressed under [`TapeStorage::Zvc`] (it is the
    /// sparsest tensor on the tape: mask zeros + ReLU zeros)
    s: TapedAct,
    mask: RowMask,
    /// statistics the forward normalized with (batch stats in training)
    mean: Vec<f32>,
    var: Vec<f32>,
    invstd: Vec<f32>,
    density: f32,
    /// estimated nonzero fraction of the layer INPUT (the forward's
    /// compound-dispatch hint) — reused by the backward to decide
    /// whether the gradW kernel gathers live input coordinates
    in_density: f32,
}

/// Per-unit tape record; `x` is the activation that ENTERED the unit
/// (moved in, not copied — the forward hands each carry buffer to the
/// tape and continues on the unit's output buffer), stored per the
/// engine's [`TapeStorage`].
enum UnitTape {
    Dense {
        x: TapedAct,
        rt: RowsTape,
    },
    Classifier {
        x: TapedAct,
        m: usize,
        d: usize,
        c: usize,
        w_name: String,
        b_name: String,
    },
    Conv {
        x: TapedAct,
        dims: (usize, usize, usize, usize),
        cs: ConvShape,
        p: usize,
        q: usize,
        rt: RowsTape,
    },
    Residual {
        x: TapedAct,
        dims: (usize, usize, usize, usize),
        /// conv1's NCHW output (conv2's input)
        h1: TapedAct,
        cs1: ConvShape,
        p1: usize,
        q1: usize,
        rt1: RowsTape,
        cs2: ConvShape,
        p2: usize,
        q2: usize,
        rt2: RowsTape,
        /// weight name of the 1x1 projection shortcut, when present
        short: Option<String>,
        short_stride: usize,
    },
    MaxPool {
        dims: (usize, usize, usize, usize),
        /// flat input index of each output's (first) argmax
        idx: Vec<u32>,
    },
    Gap {
        dims: (usize, usize, usize, usize),
    },
    Flatten,
}

fn rts_of(ut: &UnitTape) -> Vec<&RowsTape> {
    match ut {
        UnitTape::Dense { rt, .. } | UnitTape::Conv { rt, .. } => vec![rt],
        UnitTape::Residual { rt1, rt2, .. } => vec![rt1, rt2],
        _ => Vec::new(),
    }
}

// ---------------------------------------------------------------------
// tape memory accounting
// ---------------------------------------------------------------------

fn meter_act(meter: &mut MemoryMeter, unit: usize, part: &'static str, a: &TapedAct) {
    meter.alloc(TapeAlloc {
        unit,
        part,
        elems: a.len(),
        // nnz == elems means "not measured" (dense-tape runs skip the
        // counting sweep); Zvc runs always know the exact count
        nnz: a.nnz_hint().unwrap_or_else(|| a.len()),
        dense_bytes: a.dense_nbytes() as u64,
        stored_bytes: a.stored_nbytes() as u64,
    });
}

/// Per-rows-layer tape bytes: the activation record, the taped RowMask
/// (identical in both storage modes — selection state, the measured twin
/// of `memmodel`'s mask term), and the taped BN batch statistics.
fn meter_rows(meter: &mut MemoryMeter, unit: usize, part: &'static str, rt: &RowsTape) {
    meter_act(meter, unit, part, &rt.s);
    let mask_bytes = rt.mask.nbytes() as u64;
    meter.alloc(TapeAlloc {
        unit,
        part: "mask",
        elems: rt.m * rt.n,
        nnz: rt.mask.selected(),
        dense_bytes: mask_bytes,
        stored_bytes: mask_bytes,
    });
    let bn_elems = rt.mean.len() + rt.var.len() + rt.invstd.len();
    if bn_elems > 0 {
        meter.alloc(TapeAlloc {
            unit,
            part: "bn",
            elems: bn_elems,
            nnz: bn_elems,
            dense_bytes: 4 * bn_elems as u64,
            stored_bytes: 4 * bn_elems as u64,
        });
    }
}

/// Record every tape buffer of one unit with the meter (forward side).
fn meter_unit(meter: &mut MemoryMeter, unit: usize, ut: &UnitTape) {
    match ut {
        UnitTape::Dense { x, rt } => {
            meter_act(meter, unit, "x", x);
            meter_rows(meter, unit, "s", rt);
        }
        UnitTape::Classifier { x, .. } => meter_act(meter, unit, "x", x),
        UnitTape::Conv { x, rt, .. } => {
            meter_act(meter, unit, "x", x);
            meter_rows(meter, unit, "s", rt);
        }
        UnitTape::Residual { x, h1, rt1, rt2, .. } => {
            meter_act(meter, unit, "x", x);
            meter_act(meter, unit, "h1", h1);
            meter_rows(meter, unit, "s1", rt1);
            meter_rows(meter, unit, "s2", rt2);
        }
        UnitTape::MaxPool { idx, .. } => meter.alloc(TapeAlloc {
            unit,
            part: "idx",
            elems: idx.len(),
            nnz: idx.len(),
            dense_bytes: 4 * idx.len() as u64,
            stored_bytes: 4 * idx.len() as u64,
        }),
        UnitTape::Gap { .. } | UnitTape::Flatten => {}
    }
}

/// The native training engine for one model topology.  Holds only
/// immutable per-run structure (leaf index, ternary projection index
/// lists) plus reusable scratch; ALL mutable training state lives in the
/// caller's [`ModelState`], same as the artifact path.
pub struct TrainEngine {
    pub meta: Meta,
    index: BTreeMap<String, usize>,
    ridx: Vec<TernaryIndex>,
    threads: usize,
    tape: TapeStorage,
    kernels: SparseKernels,
    selection: SelectionMode,
    scratch: Scratch,
    dec: TapeDecode,
    meter: MemoryMeter,
    ops: OpsCounter,
}

impl TrainEngine {
    pub fn new(meta: &Meta, state: &ModelState) -> Result<TrainEngine> {
        if meta.units.is_empty() {
            bail!("meta {} has no topology — cannot train natively", meta.name);
        }
        if !matches!(meta.strategy.as_str(), "drs" | "dense") {
            bail!(
                "native training supports strategies drs/dense, not {:?} \
                 (oracle/random need the HLO artifacts)",
                meta.strategy
            );
        }
        ensure!(
            state.state.len() == meta.state.len(),
            "state has {} leaves, meta {} expects {}",
            state.state.len(),
            meta.name,
            meta.state.len()
        );
        let ridx = if meta.strategy == "drs" {
            ensure!(
                state.rs.len() == meta.counts.dsg && state.wps.len() == meta.counts.dsg,
                "drs model {}: {} rs / {} wps for {} dsg layers",
                meta.name,
                state.rs.len(),
                state.wps.len(),
                meta.counts.dsg
            );
            state
                .rs
                .iter()
                .map(|r| Ok(TernaryIndex::from_dense(&to_tensor(r)?)))
                .collect::<Result<_>>()?
        } else {
            Vec::new()
        };
        let index = meta
            .state
            .iter()
            .enumerate()
            .map(|(i, l)| (l.name.clone(), i))
            .collect();
        Ok(TrainEngine {
            meta: meta.clone(),
            index,
            ridx,
            threads: 1,
            tape: TapeStorage::default(),
            kernels: SparseKernels::default(),
            selection: SelectionMode::default(),
            scratch: Scratch::default(),
            dec: TapeDecode::default(),
            meter: MemoryMeter::new(),
            ops: OpsCounter::new(),
        })
    }

    /// Intra-op thread budget for the pool-backed kernels (results are
    /// bit-exact for any budget).
    pub fn with_threads(mut self, threads: usize) -> TrainEngine {
        self.threads = threads.max(1);
        self
    }

    /// Select the tape storage (see [`TapeStorage`]); training results
    /// are bit-identical either way — ZVC is lossless.
    pub fn with_tape(mut self, tape: TapeStorage) -> TrainEngine {
        self.tape = tape;
        self
    }

    /// The active tape storage.
    pub fn tape_storage(&self) -> TapeStorage {
        self.tape
    }

    /// Select the sparse kernel family ([`SparseKernels`]).  The
    /// compound kernels (default) and the output-sparse-only kernels are
    /// bit-identical — those two are baseline/parity knobs.
    /// [`SparseKernels::Simd`] is the ONE relaxed mode: forward dot
    /// products may differ from scalar by a bounded ULP count (see
    /// `docs/ARCHITECTURE.md`); backward and the tape stay bit-exact.
    pub fn with_kernels(mut self, kernels: SparseKernels) -> TrainEngine {
        self.kernels = kernels;
        self
    }

    /// Select the DRS mask-selection mode ([`SelectionMode`]):
    /// unstructured shared-threshold CSR masks (default, the paper's
    /// DRS) vs structured per-row constant fan-in in the packed `FixedK`
    /// layout.  Each mode is bit-exact across thread budgets; the two
    /// modes select different graphs, so losses differ between them.
    pub fn with_selection(mut self, selection: SelectionMode) -> TrainEngine {
        self.selection = selection;
        self
    }

    /// The active selection mode.
    pub fn selection_mode(&self) -> SelectionMode {
        self.selection
    }

    /// Measured tape memory of the most recent [`TrainEngine::train_step`]
    /// (live/peak bytes plus the per-record breakdown).
    pub fn memory(&self) -> &MemoryMeter {
        &self.meter
    }

    /// Measured realized vs dense-equivalent multiply-adds of the most
    /// recent [`TrainEngine::train_step`] (forward + backward, merged
    /// per layer — the Fig 9 reduction, recorded not modeled).
    pub fn ops(&self) -> &OpsCounter {
        &self.ops
    }

    /// The execution mode this meta trains under.
    pub fn default_mode(&self) -> Mode {
        if self.meta.strategy == "dense" {
            Mode::Dense
        } else {
            Mode::Dsg
        }
    }

    pub(crate) fn leaf(&self, name: &str) -> Result<usize> {
        self.index
            .get(name)
            .copied()
            .ok_or_else(|| anyhow::anyhow!("missing state leaf {name}"))
    }

    fn getf<'a>(&self, state: &'a ModelState, name: &str) -> Result<&'a [f32]> {
        state.state[self.leaf(name)?].as_f32()
    }

    /// One SGD + momentum update: `v <- mu v - lr g; w <- w + v`, with
    /// the velocity twin resolved by name (params.X <-> vel.X,
    /// bn.X <-> vbn.X).
    pub(crate) fn sgd_update(
        &self,
        state: &mut ModelState,
        w_name: &str,
        g: &[f32],
        lr: f32,
    ) -> Result<()> {
        let v_name = if let Some(rest) = w_name.strip_prefix("params.") {
            format!("vel.{rest}")
        } else if let Some(rest) = w_name.strip_prefix("bn.") {
            format!("vbn.{rest}")
        } else {
            bail!("no velocity twin for state leaf {w_name}")
        };
        let wi = self.leaf(w_name)?;
        let vi = self.leaf(&v_name)?;
        ensure!(wi < vi, "group order broken: {w_name} at {wi}, {v_name} at {vi}");
        let (lo, hi) = state.state.split_at_mut(vi);
        let w = lo[wi].as_f32_mut()?;
        let v = hi[0].as_f32_mut()?;
        ensure!(
            w.len() == g.len() && v.len() == g.len(),
            "{w_name}: grad len {} vs param len {}",
            g.len(),
            w.len()
        );
        for ((w, v), &g) in w.iter_mut().zip(v.iter_mut()).zip(g) {
            *v = MOMENTUM * *v - lr * g;
            *w += *v;
        }
        Ok(())
    }

    // -----------------------------------------------------------------
    // forward
    // -----------------------------------------------------------------

    /// One masked matmul layer over rows: DRS select -> masked VMM ->
    /// relu -> (training) BN -> double mask, recording everything the
    /// backward needs.  `wt` is (n, d) transposed weights (a conv's
    /// natural (K, C*r*s) layout IS this shape).
    ///
    /// `in_density` is the compound-dispatch hint (see
    /// [`NativeModel::rows_layer_ws`]); the second return value is the
    /// hint for the NEXT layer.  The kernel family comes from
    /// `self.kernels` — compound by default, output-sparse for the
    /// parity baseline; both are bit-identical, so the choice never
    /// changes training results (asserted in `tests/native_train.rs`).
    #[allow(clippy::too_many_arguments)]
    fn rows_layer_forward(
        &self,
        state: &ModelState,
        x: &[f32],
        m: usize,
        d: usize,
        wt: &[f32],
        n: usize,
        w_name: &str,
        bn_path: Option<String>,
        dsg_idx: usize,
        gamma: f32,
        sample0_rows: usize,
        mode: Mode,
        train: bool,
        storage: TapeStorage,
        in_density: f32,
        drs: &mut DrsScratch,
        ops_ctr: &mut OpsCounter,
        out: &mut Vec<f32>,
    ) -> Result<(RowsTape, f32)> {
        debug_assert_eq!(x.len(), m * d);
        ensure!(wt.len() == n * d, "{w_name}: weight is not ({n}, {d})");
        let t = self.threads;
        let mut mask = RowMask::new();
        if mode == Mode::Dsg && self.meta.strategy == "drs" && gamma > 0.0 {
            let ridx = &self.ridx[dsg_idx];
            ensure!(ridx.d == d, "{w_name}: projection d {} vs layer d {d}", ridx.d);
            let k = ridx.k;
            let wp = state.wps[dsg_idx].as_f32()?;
            drs.xp.resize(m * k, 0.0);
            parallel::project_rows_parallel_into(x, m, ridx, t, &mut drs.xp);
            drs.virt.resize(m * n, 0.0);
            parallel::matmul_parallel_into(&drs.xp, m, k, wp, n, t, &mut drs.virt);
            NativeModel::mask_select(
                self.selection,
                &drs.virt,
                n,
                gamma,
                sample0_rows,
                &mut drs.thr,
                &mut drs.pairs,
                &mut mask,
            );
        } else {
            // dense baseline / gamma = 0: keep-all mask, SAME kernels —
            // this is what makes dense vs gamma-0 bit-identical
            mask.fill_full(m, n);
        }
        out.resize(m * n, 0.0);
        let realized = match self.kernels {
            SparseKernels::Compound | SparseKernels::Simd => {
                parallel::dsg_vmm_compound_parallel_into_kt(
                    self.kernels.table(),
                    x,
                    m,
                    d,
                    wt,
                    n,
                    &mask,
                    in_density,
                    t,
                    out,
                )
            }
            SparseKernels::OutputSparse => {
                parallel::dsg_vmm_rowmask_parallel_into(x, m, d, wt, n, &mask, t, out);
                d as u64 * mask.selected() as u64
            }
        };
        ops_ctr.record(w_name, realized, (m * d * n) as u64);
        ops::relu_slice(out);
        // `out` holds s (post-relu, pre-BN) right now: tape it BEFORE
        // BN mutates the buffer.  Only training needs the tape; in Zvc
        // mode the codec reads straight from `out` — no dense clone.
        // (`storage` arrives pre-gated by forward_pass: Dense for eval.)
        let s = if train {
            TapedAct::store_ref(out, storage, t, self.kernels.table().zvc_bitmask)
        } else {
            TapedAct::Dense(Vec::new(), None)
        };
        let (mut mean, mut var, mut invstd) = (Vec::new(), Vec::new(), Vec::new());
        if let Some(path) = &bn_path {
            if train {
                batch_stats(out, m, n, &mut mean, &mut var);
            } else {
                mean = self.getf(state, &format!("bn_state.{path}.mean"))?.to_vec();
                var = self.getf(state, &format!("bn_state.{path}.var"))?.to_vec();
            }
            invstd = var.iter().map(|v| 1.0 / (v + BN_EPS).sqrt()).collect();
            let scale = self.getf(state, &format!("bn.{path}.scale"))?;
            let bias = self.getf(state, &format!("bn.{path}.bias"))?;
            apply_bn(out, n, &mean, &invstd, scale, bias);
            if self.meta.double_mask {
                NativeModel::apply_mask_rows(out, n, &mask);
            }
        }
        let density = mask.density() as f32;
        // next layer's dispatch hint — the ONE shared rule, so training
        // and inference dispatch identically
        let out_density = parallel::density_hint_after_layer(
            density,
            self.meta.use_bn && bn_path.is_some(),
            self.meta.double_mask,
        );
        Ok((
            RowsTape {
                m,
                d,
                n,
                w_name: w_name.to_string(),
                bn_path,
                s,
                mask,
                mean,
                var,
                invstd,
                density,
                in_density,
            },
            out_density,
        ))
    }

    /// One conv unit: im2col -> masked rows layer -> NCHW.  Returns the
    /// tape record, the spatial dims, and the next layer's density hint.
    #[allow(clippy::too_many_arguments)]
    fn conv_unit_forward(
        &self,
        state: &ModelState,
        x: &[f32],
        dims: (usize, usize, usize, usize),
        cs: ConvShape,
        kout: usize,
        w_name: &str,
        bn_path: Option<String>,
        dsg_idx: usize,
        gamma: f32,
        mode: Mode,
        train: bool,
        storage: TapeStorage,
        in_density: f32,
        scr: &mut Scratch,
        ops_ctr: &mut OpsCounter,
        out_nchw: &mut Vec<f32>,
    ) -> Result<(RowsTape, usize, usize, f32)> {
        let (nb, c, hh, ww) = dims;
        let (p, q) = ops::im2col_slice_into(x, nb, c, hh, ww, cs.ksize, cs.stride, cs.pad, &mut scr.rows);
        let d = c * cs.ksize * cs.ksize;
        let wflat = self.getf(state, w_name)?; // (K, C, r, s) flat == wt (K, CRS)
        let mut y = Vec::new();
        let Scratch { rows, drs, .. } = &mut *scr;
        let (rt, out_density) = self.rows_layer_forward(
            state,
            rows,
            nb * p * q,
            d,
            wflat,
            kout,
            w_name,
            bn_path,
            dsg_idx,
            gamma,
            p * q,
            mode,
            train,
            storage,
            in_density,
            drs,
            ops_ctr,
            &mut y,
        )?;
        NativeModel::rows_to_nchw_into(&y, nb, kout, p, q, out_nchw);
        Ok((rt, p, q, out_density))
    }

    /// Full taped forward.  `train` selects batch-stat BN (vs running
    /// stats) — the tape is recorded either way and simply dropped by
    /// eval callers.
    #[allow(clippy::too_many_arguments)]
    fn forward_pass(
        &self,
        state: &ModelState,
        x: &[f32],
        m: usize,
        gamma: f32,
        mode: Mode,
        train: bool,
        scr: &mut Scratch,
        tape: &mut Vec<UnitTape>,
        meter: &mut MemoryMeter,
        ops_ctr: &mut OpsCounter,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        ensure!(
            x.len() == m * self.meta.input_elems(),
            "x has {} elems, expected {} x {}",
            x.len(),
            m,
            self.meta.input_elems()
        );
        // eval tapes are discarded unread: never pay for compression there
        let st = if train { self.tape } else { TapeStorage::Dense };
        let is = &self.meta.input_shape;
        let mut carry = match is.len() {
            1 => Carry::Rows(m, is[0]),
            3 => Carry::Nchw(m, is[0], is[1], is[2]),
            r => bail!("input rank {r} unsupported"),
        };
        let mut h: Vec<f32> = x.to_vec();
        let mut densities = Vec::new();
        let mut dsg_i = 0usize;
        // compound-dispatch hint: raw input is dense
        let mut hint = 1.0f32;
        for (i, u) in self.meta.units.iter().enumerate() {
            match u {
                Unit::Dense { d_in, d_out } => {
                    let Carry::Rows(mm, d) = carry else {
                        bail!("dense unit {i} on non-rows activation")
                    };
                    ensure!(d == *d_in, "dense unit {i}: carry {d} vs d_in {d_in}");
                    let w_name = format!("params.{i}.w");
                    let wsl = self.getf(state, &w_name)?;
                    let bn_path = self.meta.use_bn.then(|| i.to_string());
                    let mut out = Vec::new();
                    let Scratch { wt, drs, .. } = &mut *scr;
                    ops::transpose_into(wsl, d, *d_out, wt);
                    let (rt, out_density) = self.rows_layer_forward(
                        state, &h, mm, d, wt, *d_out, &w_name, bn_path, dsg_i, gamma, 1, mode,
                        train, st, hint, drs, ops_ctr, &mut out,
                    )?;
                    hint = out_density;
                    densities.push(rt.density);
                    dsg_i += 1;
                    let bm = self.kernels.table().zvc_bitmask;
                    let xt = TapedAct::store(std::mem::replace(&mut h, out), st, self.threads, bm);
                    tape.push(UnitTape::Dense { x: xt, rt });
                    carry = Carry::Rows(mm, *d_out);
                }
                Unit::Classifier { d_in, d_out } => {
                    let Carry::Rows(mm, d) = carry else {
                        bail!("classifier unit {i} on non-rows activation")
                    };
                    ensure!(d == *d_in, "classifier unit {i}: carry {d} vs d_in {d_in}");
                    let w_name = format!("params.{i}.w");
                    let b_name = format!("params.{i}.b");
                    let wsl = self.getf(state, &w_name)?; // (d, c)
                    let mut out = vec![0.0f32; mm * d_out];
                    parallel::matmul_parallel_into(&h, mm, d, wsl, *d_out, self.threads, &mut out);
                    // unmasked dense layer: realized IS the baseline
                    ops_ctr.record(&w_name, (mm * d * *d_out) as u64, (mm * d * *d_out) as u64);
                    let b = self.getf(state, &b_name)?;
                    for row in out.chunks_exact_mut(*d_out) {
                        for (v, bb) in row.iter_mut().zip(b) {
                            *v += *bb;
                        }
                    }
                    let bm = self.kernels.table().zvc_bitmask;
                    tape.push(UnitTape::Classifier {
                        x: TapedAct::store(std::mem::replace(&mut h, out), st, self.threads, bm),
                        m: mm,
                        d,
                        c: *d_out,
                        w_name,
                        b_name,
                    });
                    carry = Carry::Rows(mm, *d_out);
                }
                Unit::Conv { c_in, c_out, ksize, stride, pad } => {
                    let Carry::Nchw(nb, c, hh, ww) = carry else {
                        bail!("conv unit {i} on non-NCHW activation")
                    };
                    ensure!(c == *c_in, "conv unit {i}: carry {c} vs c_in {c_in}");
                    let cs = ConvShape { ksize: *ksize, stride: *stride, pad: *pad };
                    let bn_path = self.meta.use_bn.then(|| i.to_string());
                    let mut out = Vec::new();
                    let (rt, p, q, out_density) = self.conv_unit_forward(
                        state,
                        &h,
                        (nb, c, hh, ww),
                        cs,
                        *c_out,
                        &format!("params.{i}.w"),
                        bn_path,
                        dsg_i,
                        gamma,
                        mode,
                        train,
                        st,
                        hint,
                        scr,
                        ops_ctr,
                        &mut out,
                    )?;
                    hint = out_density;
                    densities.push(rt.density);
                    dsg_i += 1;
                    let bm = self.kernels.table().zvc_bitmask;
                    tape.push(UnitTape::Conv {
                        x: TapedAct::store(std::mem::replace(&mut h, out), st, self.threads, bm),
                        dims: (nb, c, hh, ww),
                        cs,
                        p,
                        q,
                        rt,
                    });
                    carry = Carry::Nchw(nb, *c_out, p, q);
                }
                Unit::Residual { c_in, c_out, stride } => {
                    let Carry::Nchw(nb, c, hh, ww) = carry else {
                        bail!("residual unit {i} on non-NCHW activation")
                    };
                    ensure!(c == *c_in, "residual unit {i}: carry {c} vs c_in {c_in}");
                    let cs1 = ConvShape { ksize: 3, stride: *stride, pad: 1 };
                    let cs2 = ConvShape { ksize: 3, stride: 1, pad: 1 };
                    let mut h1 = Vec::new();
                    let (rt1, p1, q1, h1_density) = self.conv_unit_forward(
                        state,
                        &h,
                        (nb, c, hh, ww),
                        cs1,
                        *c_out,
                        &format!("params.{i}.conv1.w"),
                        self.meta.use_bn.then(|| format!("{i}.bn1")),
                        dsg_i,
                        gamma,
                        mode,
                        train,
                        st,
                        hint,
                        scr,
                        ops_ctr,
                        &mut h1,
                    )?;
                    densities.push(rt1.density);
                    dsg_i += 1;
                    let mut h2 = Vec::new();
                    let (rt2, p2, q2, _) = self.conv_unit_forward(
                        state,
                        &h1,
                        (nb, *c_out, p1, q1),
                        cs2,
                        *c_out,
                        &format!("params.{i}.conv2.w"),
                        self.meta.use_bn.then(|| format!("{i}.bn2")),
                        dsg_i,
                        gamma,
                        mode,
                        train,
                        st,
                        h1_density,
                        scr,
                        ops_ctr,
                        &mut h2,
                    )?;
                    densities.push(rt2.density);
                    dsg_i += 1;
                    // the residual sum merges the masked main path with
                    // the (dense) shortcut: treat the output as dense
                    hint = 1.0;
                    let short = (*stride != 1 || c_in != c_out)
                        .then(|| format!("params.{i}.short.w"));
                    if let Some(sname) = &short {
                        // plain (unmasked, no relu/BN) 1x1 projection
                        let (ps, qs) =
                            ops::im2col_slice_into(&h, nb, c, hh, ww, 1, *stride, 0, &mut scr.rows);
                        debug_assert_eq!((ps, qs), (p2, q2));
                        let wsl = self.getf(state, sname)?; // (K, c)
                        ops::transpose_into(wsl, *c_out, c, &mut scr.wt); // (c, K)
                        let rsz = nb * p2 * q2;
                        let mut y = vec![0.0f32; rsz * *c_out];
                        parallel::matmul_parallel_into(
                            &scr.rows, rsz, c, &scr.wt, *c_out, self.threads, &mut y,
                        );
                        let mut sc = Vec::new();
                        NativeModel::rows_to_nchw_into(&y, nb, *c_out, p2, q2, &mut sc);
                        for (v, s) in h2.iter_mut().zip(&sc) {
                            *v += *s;
                        }
                    } else {
                        debug_assert_eq!(h2.len(), h.len());
                        for (v, s) in h2.iter_mut().zip(&h) {
                            *v += *s;
                        }
                    }
                    let bm = self.kernels.table().zvc_bitmask;
                    tape.push(UnitTape::Residual {
                        x: TapedAct::store(std::mem::replace(&mut h, h2), st, self.threads, bm),
                        dims: (nb, c, hh, ww),
                        h1: TapedAct::store(h1, st, self.threads, bm),
                        cs1,
                        p1,
                        q1,
                        rt1,
                        cs2,
                        p2,
                        q2,
                        rt2,
                        short,
                        short_stride: *stride,
                    });
                    carry = Carry::Nchw(nb, *c_out, p2, q2);
                }
                Unit::MaxPool { size } => {
                    let Carry::Nchw(nb, c, hh, ww) = carry else {
                        bail!("maxpool unit {i} on non-NCHW activation")
                    };
                    let mut out = Vec::new();
                    let mut idx = Vec::new();
                    let (pn, pc, ph, pw) =
                        maxpool_fwd(&h, (nb, c, hh, ww), *size, &mut out, &mut idx);
                    // window max is zero only when the whole window is
                    hint = 1.0 - (1.0 - hint).powi((*size * *size) as i32);
                    tape.push(UnitTape::MaxPool { dims: (nb, c, hh, ww), idx });
                    h = out;
                    carry = Carry::Nchw(pn, pc, ph, pw);
                }
                Unit::GlobalAvgPool => {
                    let Carry::Nchw(nb, c, hh, ww) = carry else {
                        bail!("gap unit {i} on non-NCHW activation")
                    };
                    let mut out = vec![0.0f32; nb * c];
                    for ni in 0..nb {
                        for ci in 0..c {
                            let plane = &h[(ni * c + ci) * hh * ww..(ni * c + ci + 1) * hh * ww];
                            let acc: f64 = plane.iter().map(|&v| v as f64).sum();
                            out[ni * c + ci] = (acc / (hh * ww) as f64) as f32;
                        }
                    }
                    tape.push(UnitTape::Gap { dims: (nb, c, hh, ww) });
                    hint = 1.0; // plane averages are essentially dense
                    h = out;
                    carry = Carry::Rows(nb, c);
                }
                Unit::Flatten => {
                    carry = match carry {
                        Carry::Rows(mm, d) => Carry::Rows(mm, d),
                        Carry::Nchw(nb, c, hh, ww) => Carry::Rows(nb, c * hh * ww),
                    };
                    tape.push(UnitTape::Flatten);
                }
            }
        }
        let Carry::Rows(mm, c) = carry else {
            bail!("forward ended on an NCHW activation")
        };
        ensure!(
            mm == m && c == self.meta.classes,
            "forward produced shape [{mm}, {c}]"
        );
        if train {
            // everything taped is live at the forward/backward turnover:
            // this is the peak the memory claim is about
            for (i, ut) in tape.iter().enumerate() {
                meter_unit(meter, i, ut);
            }
        }
        Ok((h, densities))
    }

    /// Inference/eval forward: running-stat BN, no state mutation.
    pub fn forward_eval(
        &mut self,
        state: &ModelState,
        x: &[f32],
        m: usize,
        gamma: f32,
        mode: Mode,
    ) -> Result<Vec<f32>> {
        let mut scr = std::mem::take(&mut self.scratch);
        let mut tape = Vec::new();
        let mut meter = MemoryMeter::new(); // untouched: eval doesn't meter
        let mut ops_ctr = OpsCounter::new(); // discarded: eval isn't reported
        let r = self.forward_pass(
            state, x, m, gamma, mode, false, &mut scr, &mut tape, &mut meter, &mut ops_ctr,
        );
        self.scratch = scr;
        r.map(|(logits, _)| logits)
    }

    // -----------------------------------------------------------------
    // backward
    // -----------------------------------------------------------------

    /// Backward through one masked rows layer: double mask -> BN -> relu
    /// -> masked VMM backward (dX + dW), with the gradients COLLECTED
    /// into `gs` (never applied here — the walk is read-only on state).
    /// `conv_weight`: the state weight is already (n, d)-transposed
    /// (conv natural layout), so the grad is pushed without a layout
    /// flip.  `sbuf`: decompress scratch for the post-relu tape (reused
    /// across units; a no-op view for dense-stored records).
    ///
    /// Under [`SparseKernels::Compound`] the gradW kernel reads only the
    /// LIVE input coordinates (gathered once into `nzx_scr` when the
    /// taped `in_density` hint says the input is sparse), and dX reads
    /// only the selected, nonzero gradient entries — both bit-identical
    /// to the output-sparse kernels.
    #[allow(clippy::too_many_arguments)]
    fn rows_layer_backward(
        &self,
        state: &ModelState,
        x: &[f32],
        dout: &mut [f32],
        rt: &RowsTape,
        wt_scr: &mut Vec<f32>,
        gwt_scr: &mut Vec<f32>,
        nzx_scr: &mut NzIndex,
        dx: &mut [f32],
        conv_weight: bool,
        sbuf: &mut Vec<f32>,
        ops_ctr: &mut OpsCounter,
        gs: &mut GradStore,
    ) -> Result<()> {
        let (m, d, n) = (rt.m, rt.d, rt.n);
        debug_assert_eq!(dout.len(), m * n);
        debug_assert_eq!(dx.len(), m * d);
        let s = rt.s.slice(sbuf);
        if let Some(path) = &rt.bn_path {
            if self.meta.double_mask {
                // forward: out = BN(s) * mask  =>  dBN = dout * mask
                NativeModel::apply_mask_rows(dout, n, &rt.mask);
            }
            let scale = self.getf(state, &format!("bn.{path}.scale"))?;
            let (gscale, gbias) = bn_backward(dout, s, &rt.mean, &rt.invstd, scale, m, n);
            relu_backward(dout, s);
            gs.push(format!("bn.{path}.scale"), gscale);
            gs.push(format!("bn.{path}.bias"), gbias);
        } else {
            relu_backward(dout, s);
        }
        {
            let wsl = self.getf(state, &rt.w_name)?;
            let wt: &[f32] = if conv_weight {
                wsl // already (n, d)
            } else {
                ops::transpose_into(wsl, d, n, wt_scr);
                wt_scr
            };
            gwt_scr.resize(n * d, 0.0);
            let dense_eq = 2 * (m * d * n) as u64; // dX + dW baselines
            match self.kernels {
                SparseKernels::Compound | SparseKernels::Simd => {
                    let kt = self.kernels.table();
                    let r_dx = parallel::dsg_vmm_rowmask_backward_compound_parallel_into_kt(
                        kt, dout, m, d, wt, n, &rt.mask, self.threads, dx,
                    );
                    // gather live input coordinates only when the
                    // forward's measured hint says the gather pays
                    let r_dw = if rt.in_density < parallel::compound_cutoff() {
                        nzx_scr.fill_from_rows(x, m, d);
                        parallel::dsg_vmm_rowmask_gradw_compound_parallel_into_kt(
                            kt, x, dout, m, d, n, &rt.mask, nzx_scr, self.threads, gwt_scr,
                        )
                    } else {
                        parallel::dsg_vmm_rowmask_gradw_parallel_into_kt(
                            kt, x, dout, m, d, n, &rt.mask, self.threads, gwt_scr,
                        );
                        // the kernel executes d madds per live (i, j)
                        // pair (g == 0 skipped) — the same measure the
                        // compound dX kernel just counted
                        r_dx
                    };
                    ops_ctr.record(&rt.w_name, r_dx + r_dw, dense_eq);
                }
                SparseKernels::OutputSparse => {
                    parallel::dsg_vmm_rowmask_backward_parallel_into(
                        dout, m, d, wt, n, &rt.mask, self.threads, dx,
                    );
                    parallel::dsg_vmm_rowmask_gradw_parallel_into(
                        x, dout, m, d, n, &rt.mask, self.threads, gwt_scr,
                    );
                    // both kernels skip g == 0: count what they touched
                    // so the baseline is measured, not nominal
                    let live = parallel::live_grad_count(dout, n, &rt.mask);
                    ops_ctr.record(&rt.w_name, 2 * d as u64 * live, dense_eq);
                }
            }
        }
        if conv_weight {
            gs.push(rt.w_name.clone(), gwt_scr.clone());
        } else {
            let mut gw = Vec::new();
            ops::transpose_into(gwt_scr, n, d, &mut gw); // (d, n)
            gs.push(rt.w_name.clone(), gw);
        }
        Ok(())
    }

    /// Backward through one conv unit (NCHW in/out).
    #[allow(clippy::too_many_arguments)]
    fn conv_unit_backward(
        &self,
        state: &ModelState,
        x: &[f32],
        dims: (usize, usize, usize, usize),
        cs: ConvShape,
        p: usize,
        q: usize,
        rt: &RowsTape,
        dout_nchw: &[f32],
        scr: &mut Scratch,
        sbuf: &mut Vec<f32>,
        ops_ctr: &mut OpsCounter,
        dx_nchw: &mut Vec<f32>,
        gs: &mut GradStore,
    ) -> Result<()> {
        let (nb, c, hh, ww) = dims;
        let kout = rt.n;
        // recompute im2col of the unit input (cheaper than taping it —
        // the paper's training-memory argument applied to our own tape)
        let (p2, q2) = ops::im2col_slice_into(x, nb, c, hh, ww, cs.ksize, cs.stride, cs.pad, &mut scr.rows);
        debug_assert_eq!((p2, q2), (p, q));
        nchw_to_rows_into(dout_nchw, nb, kout, p, q, &mut scr.dyr);
        let mut dx_rows = vec![0.0f32; rt.m * rt.d];
        let Scratch { rows, dyr, wt, gwt, nzx, .. } = &mut *scr;
        self.rows_layer_backward(
            state, rows, dyr, rt, wt, gwt, nzx, &mut dx_rows, true, sbuf, ops_ctr, gs,
        )?;
        ops::col2im_slice_into(&dx_rows, nb, c, hh, ww, cs.ksize, cs.stride, cs.pad, dx_nchw);
        Ok(())
    }

    /// Backward through one tape unit: returns the gradient wrt the
    /// unit's input, collecting this unit's parameter gradients into
    /// `gs` (state is never mutated — pure).  `dec` is the shared
    /// decompress scratch: compressed tape records are expanded into it
    /// on demand and the buffers are reused across the whole backward
    /// walk.
    fn unit_backward(
        &self,
        state: &ModelState,
        ut: &UnitTape,
        mut dout: Vec<f32>,
        scr: &mut Scratch,
        dec: &mut TapeDecode,
        ops_ctr: &mut OpsCounter,
        gs: &mut GradStore,
    ) -> Result<Vec<f32>> {
        let TapeDecode { x: xbuf, s: sbuf } = dec;
        match ut {
            UnitTape::Dense { x, rt } => {
                let xs = x.slice(xbuf);
                let mut dx = vec![0.0f32; rt.m * rt.d];
                let Scratch { wt, gwt, nzx, .. } = &mut *scr;
                self.rows_layer_backward(
                    state, xs, &mut dout, rt, wt, gwt, nzx, &mut dx, false, sbuf, ops_ctr, gs,
                )?;
                Ok(dx)
            }
            UnitTape::Classifier { x, m, d, c, w_name, b_name } => {
                let xs = x.slice(xbuf);
                // dX = dL @ W^T
                let mut dx = vec![0.0f32; m * d];
                {
                    let wsl = self.getf(state, w_name)?; // (d, c)
                    ops::transpose_into(wsl, *d, *c, &mut scr.wt); // (c, d)
                    parallel::matmul_parallel_into(&dout, *m, *c, &scr.wt, *d, self.threads, &mut dx);
                }
                // dW^T (c, d) = dL^T @ X, then flip to (d, c)
                let mut dlt = Vec::new();
                ops::transpose_into(&dout, *m, *c, &mut dlt);
                scr.gwt.resize(c * d, 0.0);
                parallel::matmul_parallel_into(&dlt, *c, *m, xs, *d, self.threads, &mut scr.gwt);
                let mut gw = Vec::new();
                ops::transpose_into(&scr.gwt, *c, *d, &mut gw);
                let mut gb = vec![0.0f64; *c];
                for row in dout.chunks_exact(*c) {
                    for j in 0..*c {
                        gb[j] += row[j] as f64;
                    }
                }
                let gb: Vec<f32> = gb.iter().map(|&v| v as f32).collect();
                gs.push(w_name.clone(), gw);
                gs.push(b_name.clone(), gb);
                Ok(dx)
            }
            UnitTape::Conv { x, dims, cs, p, q, rt } => {
                let xs = x.slice(xbuf);
                let mut dx = Vec::new();
                self.conv_unit_backward(
                    state, xs, *dims, *cs, *p, *q, rt, &dout, scr, sbuf, ops_ctr, &mut dx, gs,
                )?;
                Ok(dx)
            }
            UnitTape::Residual {
                x,
                dims,
                h1,
                cs1,
                p1,
                q1,
                rt1,
                cs2,
                p2,
                q2,
                rt2,
                short,
                short_stride,
            } => {
                let (nb, c, hh, ww) = *dims;
                // main path: conv2 then conv1 (the decompress scratch is
                // reused: h1's view ends before x needs the buffer)
                let mut d_h1 = Vec::new();
                {
                    let h1s = h1.slice(xbuf);
                    self.conv_unit_backward(
                        state, h1s, (nb, rt1.n, *p1, *q1), *cs2, *p2, *q2, rt2, &dout, scr,
                        sbuf, ops_ctr, &mut d_h1, gs,
                    )?;
                }
                let xs = x.slice(xbuf);
                let mut dx = Vec::new();
                self.conv_unit_backward(
                    state, xs, (nb, c, hh, ww), *cs1, *p1, *q1, rt1, &d_h1, scr, sbuf,
                    ops_ctr, &mut dx, gs,
                )?;
                if let Some(sname) = short {
                    // shortcut: plain 1x1 conv backward
                    let kout = rt2.n;
                    let rsz = nb * p2 * q2;
                    nchw_to_rows_into(&dout, nb, kout, *p2, *q2, &mut scr.dyr);
                    let mut dxs_rows = vec![0.0f32; rsz * c];
                    {
                        let wsl = self.getf(state, sname)?; // (K, c) natural
                        parallel::matmul_parallel_into(
                            &scr.dyr, rsz, kout, wsl, c, self.threads, &mut dxs_rows,
                        );
                    }
                    let (ps, qs) =
                        ops::im2col_slice_into(xs, nb, c, hh, ww, 1, *short_stride, 0, &mut scr.rows);
                    debug_assert_eq!((ps, qs), (*p2, *q2));
                    let mut dyt = Vec::new();
                    ops::transpose_into(&scr.dyr, rsz, kout, &mut dyt); // (K, R)
                    scr.gwt.resize(kout * c, 0.0);
                    parallel::matmul_parallel_into(
                        &dyt, kout, rsz, &scr.rows, c, self.threads, &mut scr.gwt,
                    );
                    let mut dxs = Vec::new();
                    ops::col2im_slice_into(&dxs_rows, nb, c, hh, ww, 1, *short_stride, 0, &mut dxs);
                    for (v, s) in dx.iter_mut().zip(&dxs) {
                        *v += *s;
                    }
                    gs.push(sname.clone(), scr.gwt.clone());
                } else {
                    debug_assert_eq!(dx.len(), dout.len());
                    for (v, s) in dx.iter_mut().zip(&dout) {
                        *v += *s;
                    }
                }
                Ok(dx)
            }
            UnitTape::MaxPool { dims, idx } => {
                let (nb, c, hh, ww) = *dims;
                ensure!(idx.len() == dout.len(), "maxpool tape/grad mismatch");
                let mut dx = vec![0.0f32; nb * c * hh * ww];
                for (o, &src) in idx.iter().enumerate() {
                    dx[src as usize] += dout[o];
                }
                Ok(dx)
            }
            UnitTape::Gap { dims } => {
                let (nb, c, hh, ww) = *dims;
                let scale = 1.0 / (hh * ww) as f32;
                let mut dx = vec![0.0f32; nb * c * hh * ww];
                for ni in 0..nb {
                    for ci in 0..c {
                        let g = dout[ni * c + ci] * scale;
                        for t in dx[(ni * c + ci) * hh * ww..(ni * c + ci + 1) * hh * ww].iter_mut()
                        {
                            *t = g;
                        }
                    }
                }
                Ok(dx)
            }
            UnitTape::Flatten => Ok(dout), // shape-only change
        }
    }

    /// BN running-stat update from the batch stats recorded on the tape
    /// (python: `new = 0.9 old + 0.1 batch`, biased variance).
    fn update_bn_state(&self, state: &mut ModelState, tape: &[UnitTape]) -> Result<()> {
        for ut in tape {
            for rt in rts_of(ut) {
                let Some(path) = &rt.bn_path else { continue };
                for (leaf, batch) in [
                    (format!("bn_state.{path}.mean"), &rt.mean),
                    (format!("bn_state.{path}.var"), &rt.var),
                ] {
                    let i = self.leaf(&leaf)?;
                    let run = state.state[i].as_f32_mut()?;
                    ensure!(run.len() == batch.len(), "{leaf}: stat len mismatch");
                    for (r, &b) in run.iter_mut().zip(batch) {
                        *r = BN_MOMENTUM * *r + (1.0 - BN_MOMENTUM) * b;
                    }
                }
            }
        }
        Ok(())
    }

    /// One full Algorithm-1 training step on a prepared batch: taped
    /// forward (training BN + running-stat update), softmax
    /// cross-entropy, masked backward, SGD + momentum updates — all in
    /// place on `state`.
    pub fn train_step(
        &mut self,
        state: &mut ModelState,
        x: &[f32],
        y: &[i32],
        gamma: f32,
        lr: f32,
        mode: Mode,
    ) -> Result<TrainOut> {
        ensure!(!y.is_empty(), "empty batch");
        let m = y.len();
        let c = self.meta.classes;
        for &yi in y {
            ensure!((0..c as i32).contains(&yi), "label {yi} out of range 0..{c}");
        }
        let mut scr = std::mem::take(&mut self.scratch);
        let mut dec = std::mem::take(&mut self.dec);
        let mut meter = std::mem::take(&mut self.meter);
        let mut ops_ctr = std::mem::take(&mut self.ops);
        meter.reset();
        ops_ctr.reset();
        let mut tape: Vec<UnitTape> = Vec::new();
        let r: Result<TrainOut> = (|| {
            let (logits, densities) = self.forward_pass(
                state, x, m, gamma, mode, true, &mut scr, &mut tape, &mut meter, &mut ops_ctr,
            )?;
            self.update_bn_state(state, &tape)?;
            let (loss, acc, dlogits) = softmax_xent(&logits, y, m, c);
            let mut dcarry = dlogits;
            // pop as we go: each consumed record's tape bytes are
            // RELEASED (dropped + metered from the meter's own alloc
            // records — tape.len() after the pop IS the popped unit's
            // index), so live memory decays over the backward exactly as
            // the paper's footprint model assumes
            let mut gs = GradStore::default();
            while let Some(ut) = tape.pop() {
                // fault site: a transient failure reading the compressed
                // tape back.  The step has already mutated `state` in
                // place (BN running stats), so there is no in-place
                // retry — the error kills the run and recovery is
                // resume-from-last-checkpoint, which replays this step
                // deterministically (bit-identical; asserted in
                // tests/native_train.rs).
                if self.tape == TapeStorage::Zvc {
                    faults::check_io("tape.decompress")
                        .context("decompressing taped activations")?;
                }
                dcarry =
                    self.unit_backward(state, &ut, dcarry, &mut scr, &mut dec, &mut ops_ctr, &mut gs)?;
                meter.free_unit(tape.len());
            }
            // apply phase: the backward above read only pre-update
            // weights (each unit's backward touches its own leaves
            // once), so collect-then-apply produces the exact bits the
            // old inline-apply walk did — and gives `leaf_step` a pure
            // gradient path for the data-parallel trainer.
            for (name, g) in gs.take() {
                self.sgd_update(state, &name, &g, lr)?;
            }
            Ok(TrainOut { loss, acc, densities })
        })();
        self.scratch = scr;
        self.dec = dec;
        self.meter = meter;
        self.ops = ops_ctr;
        r
    }

    /// One PURE leaf step for the data-parallel trainer: taped forward +
    /// masked backward over a leaf's rows, returning raw sums (loss,
    /// correct, densities, leaf-local BN batch stats) and the collected
    /// parameter gradients — `state` is never mutated.  `denom` is the
    /// GLOBAL batch size: dlogits carry `1/denom`, so summing leaf
    /// gradients through the pinned reduction tree yields the global
    /// mean-loss gradient.  Purity is what makes a retried leaf
    /// bit-exact and a kill at any fault site recoverable: nothing
    /// commits until the coordinator has every leaf.
    pub(crate) fn leaf_step(
        &mut self,
        state: &ModelState,
        x: &[f32],
        y: &[i32],
        gamma: f32,
        denom: usize,
        mode: Mode,
    ) -> Result<LeafOut> {
        ensure!(!y.is_empty(), "empty leaf");
        let m = y.len();
        let c = self.meta.classes;
        for &yi in y {
            ensure!((0..c as i32).contains(&yi), "label {yi} out of range 0..{c}");
        }
        let mut scr = std::mem::take(&mut self.scratch);
        let mut dec = std::mem::take(&mut self.dec);
        let mut meter = std::mem::take(&mut self.meter);
        let mut ops_ctr = std::mem::take(&mut self.ops);
        meter.reset();
        ops_ctr.reset();
        let mut tape: Vec<UnitTape> = Vec::new();
        let r: Result<LeafOut> = (|| {
            let (logits, _densities) = self.forward_pass(
                state, x, m, gamma, mode, true, &mut scr, &mut tape, &mut meter, &mut ops_ctr,
            )?;
            // exact per-leaf counts (selected, total) and BN batch stats
            // off the tape, in forward order — integers and f64/f32 sums
            // the coordinator combines through the pinned tree
            let mut densities: Vec<(u64, u64)> = Vec::new();
            let mut bn: Vec<BnStat> = Vec::new();
            for ut in &tape {
                for rt in rts_of(ut) {
                    densities.push((
                        rt.mask.selected() as u64,
                        (rt.mask.rows() * rt.mask.width()) as u64,
                    ));
                    if let Some(path) = &rt.bn_path {
                        bn.push(BnStat {
                            path: path.clone(),
                            rows: rt.m as u64,
                            mean: rt.mean.clone(),
                            var: rt.var.clone(),
                        });
                    }
                }
            }
            let (loss_sum, correct, dlogits) = softmax_xent_sums(&logits, y, m, c, denom);
            let mut dcarry = dlogits;
            let mut gs = GradStore::default();
            while let Some(ut) = tape.pop() {
                if self.tape == TapeStorage::Zvc {
                    faults::check_io("tape.decompress")
                        .context("decompressing taped activations")?;
                }
                dcarry =
                    self.unit_backward(state, &ut, dcarry, &mut scr, &mut dec, &mut ops_ctr, &mut gs)?;
                meter.free_unit(tape.len());
            }
            Ok(LeafOut {
                rows: m as u32,
                loss_sum,
                correct: correct as u32,
                densities,
                bn,
                grads: gs.take(),
            })
        })();
        self.scratch = scr;
        self.dec = dec;
        self.meter = meter;
        self.ops = ops_ctr;
        r
    }
}

// ---------------------------------------------------------------------
// layer math helpers
// ---------------------------------------------------------------------

/// Per-column mean and biased variance over (m, n) rows (f64 accum).
fn batch_stats(s: &[f32], m: usize, n: usize, mean: &mut Vec<f32>, var: &mut Vec<f32>) {
    let mut acc = vec![0.0f64; n];
    for row in s.chunks_exact(n) {
        for j in 0..n {
            acc[j] += row[j] as f64;
        }
    }
    mean.clear();
    mean.extend(acc.iter().map(|&a| (a / m as f64) as f32));
    acc.fill(0.0);
    for row in s.chunks_exact(n) {
        for j in 0..n {
            let dv = row[j] as f64 - mean[j] as f64;
            acc[j] += dv * dv;
        }
    }
    var.clear();
    var.extend(acc.iter().map(|&a| (a / m as f64) as f32));
}

/// y = (x - mean) * invstd * scale + bias, rows layout, in place.
fn apply_bn(out: &mut [f32], n: usize, mean: &[f32], invstd: &[f32], scale: &[f32], bias: &[f32]) {
    for row in out.chunks_exact_mut(n) {
        for j in 0..n {
            row[j] = (row[j] - mean[j]) * invstd[j] * scale[j] + bias[j];
        }
    }
}

/// Full training-mode BN backward, in place on `dout` (which becomes
/// dL/ds), returning (dscale, dbias).  Mean and variance are functions
/// of s, so the column-mean correction terms are included:
/// ds = scale*invstd * (dout - mean_i(dout) - xhat * mean_i(dout*xhat)).
fn bn_backward(
    dout: &mut [f32],
    s: &[f32],
    mean: &[f32],
    invstd: &[f32],
    scale: &[f32],
    m: usize,
    n: usize,
) -> (Vec<f32>, Vec<f32>) {
    let mut sb = vec![0.0f64; n]; // sum dout
    let mut sxh = vec![0.0f64; n]; // sum dout * xhat
    for (row, srow) in dout.chunks_exact(n).zip(s.chunks_exact(n)) {
        for j in 0..n {
            let xh = ((srow[j] - mean[j]) * invstd[j]) as f64;
            sb[j] += row[j] as f64;
            sxh[j] += row[j] as f64 * xh;
        }
    }
    let mf = m as f64;
    for (row, srow) in dout.chunks_exact_mut(n).zip(s.chunks_exact(n)) {
        for j in 0..n {
            let xh = ((srow[j] - mean[j]) * invstd[j]) as f64;
            let t = row[j] as f64 - sb[j] / mf - xh * (sxh[j] / mf);
            row[j] = ((scale[j] * invstd[j]) as f64 * t) as f32;
        }
    }
    (
        sxh.iter().map(|&v| v as f32).collect(),
        sb.iter().map(|&v| v as f32).collect(),
    )
}

/// relu': zero the gradient wherever the stored post-relu activation is
/// zero (masked-away neurons land here too, since their y was never
/// computed and stayed 0).
fn relu_backward(dout: &mut [f32], s: &[f32]) {
    for (v, &sv) in dout.iter_mut().zip(s) {
        if sv <= 0.0 {
            *v = 0.0;
        }
    }
}

/// Maxpool forward that records each output's (first) argmax flat input
/// index for exact gradient routing.
fn maxpool_fwd(
    xd: &[f32],
    dims: (usize, usize, usize, usize),
    size: usize,
    out: &mut Vec<f32>,
    idx: &mut Vec<u32>,
) -> (usize, usize, usize, usize) {
    let (n, c, h, w) = dims;
    assert!(xd.len() <= u32::MAX as usize, "activation too large for u32 pool indices");
    let (ph, pw) = (h / size, w / size);
    out.clear();
    out.resize(n * c * ph * pw, 0.0);
    idx.clear();
    idx.resize(n * c * ph * pw, 0);
    for ni in 0..n {
        for ci in 0..c {
            for y in 0..ph {
                for x in 0..pw {
                    let mut best = f32::NEG_INFINITY;
                    let mut bi = 0usize;
                    for dy in 0..size {
                        for dx in 0..size {
                            let off = ((ni * c + ci) * h + y * size + dy) * w + x * size + dx;
                            let v = xd[off];
                            if v > best {
                                best = v;
                                bi = off;
                            }
                        }
                    }
                    let o = ((ni * c + ci) * ph + y) * pw + x;
                    out[o] = best;
                    idx[o] = bi as u32;
                }
            }
        }
    }
    (n, c, ph, pw)
}

/// NCHW -> rows (N*P*Q, K): the inverse of
/// [`NativeModel::rows_to_nchw_into`], used to route conv gradients back
/// into the rows layout the masked kernels operate in.
fn nchw_to_rows_into(x: &[f32], n: usize, k: usize, p: usize, q: usize, out: &mut Vec<f32>) {
    debug_assert_eq!(x.len(), n * k * p * q);
    out.resize(n * p * q * k, 0.0); // fully overwritten below
    for ni in 0..n {
        for ki in 0..k {
            for pi in 0..p {
                for qi in 0..q {
                    out[((ni * p + pi) * q + qi) * k + ki] =
                        x[((ni * k + ki) * p + pi) * q + qi];
                }
            }
        }
    }
}

/// Softmax cross-entropy over (m, c) rows returning RAW sums — loss as
/// an f64 sum over rows, correct as a count — plus dL/dlogits scaled by
/// `1/denom`.  A single-process step passes `denom = m` (mean loss); a
/// data-parallel leaf passes the GLOBAL batch size so leaf gradients sum
/// to the global mean-loss gradient without any post-hoc rescale (which
/// would not be bit-identical to the single-shard division).
pub(crate) fn softmax_xent_sums(
    logits: &[f32],
    y: &[i32],
    m: usize,
    c: usize,
    denom: usize,
) -> (f64, usize, Vec<f32>) {
    debug_assert_eq!(logits.len(), m * c);
    let mut dl = vec![0.0f32; m * c];
    let mut loss = 0.0f64;
    let mut correct = 0usize;
    for i in 0..m {
        let row = &logits[i * c..(i + 1) * c];
        let yi = y[i] as usize;
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut se = 0.0f32;
        for &v in row {
            se += (v - mx).exp();
        }
        let lse = mx + se.ln();
        loss += (lse - row[yi]) as f64;
        if crate::serve::argmax(row) == yi {
            correct += 1;
        }
        let drow = &mut dl[i * c..(i + 1) * c];
        for (j, dv) in drow.iter_mut().enumerate() {
            let p = (row[j] - lse).exp();
            *dv = (p - if j == yi { 1.0 } else { 0.0 }) / denom as f32;
        }
    }
    (loss, correct, dl)
}

/// Mean softmax cross-entropy + accuracy + dL/dlogits over (m, c) rows.
pub(crate) fn softmax_xent(logits: &[f32], y: &[i32], m: usize, c: usize) -> (f32, f32, Vec<f32>) {
    let (loss, correct, dl) = softmax_xent_sums(logits, y, m, c, m);
    ((loss / m as f64) as f32, correct as f32 / m as f32, dl)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;
    use crate::util::Pcg32;

    #[test]
    fn softmax_xent_known_values() {
        // uniform logits: loss = ln(c), grad rows sum to zero
        let m = 3;
        let c = 4;
        let logits = vec![0.0f32; m * c];
        let y = vec![0, 1, 2];
        let (loss, _acc, dl) = softmax_xent(&logits, &y, m, c);
        assert!((loss - (c as f32).ln()).abs() < 1e-6);
        for i in 0..m {
            let rs: f32 = dl[i * c..(i + 1) * c].iter().sum();
            assert!(rs.abs() < 1e-6, "row {i} grad sum {rs}");
            // true class entry is (1/c - 1)/m, others 1/(c*m)
            assert!((dl[i * c + y[i] as usize] - (0.25 - 1.0) / 3.0).abs() < 1e-6);
        }
    }

    #[test]
    fn batch_stats_match_definition() {
        let s = vec![1.0f32, 10.0, 3.0, 20.0, 5.0, 30.0];
        let (mut mean, mut var) = (Vec::new(), Vec::new());
        batch_stats(&s, 3, 2, &mut mean, &mut var);
        assert_eq!(mean, vec![3.0, 20.0]);
        // biased variance: mean of squared deviations
        assert!((var[0] - 8.0 / 3.0).abs() < 1e-6);
        assert!((var[1] - 200.0 / 3.0).abs() < 1e-5);
    }

    #[test]
    fn bn_backward_finite_difference() {
        // scalar check of the full BN backward (mean/var are functions
        // of the input) against central differences on a tiny layer
        let (m, n) = (5usize, 3usize);
        let mut rng = Pcg32::seeded(21);
        let s: Vec<f32> = rng.normal_vec(m * n, 1.0).iter().map(|v| v.abs()).collect();
        let scale: Vec<f32> = rng.normal_vec(n, 0.3).iter().map(|v| 1.0 + v).collect();
        let bias: Vec<f32> = rng.normal_vec(n, 0.3);
        let upstream: Vec<f32> = rng.normal_vec(m * n, 1.0);
        // loss(s) = <upstream, BN(s)>
        let loss = |sv: &[f32]| -> f64 {
            let (mut mean, mut var) = (Vec::new(), Vec::new());
            batch_stats(sv, m, n, &mut mean, &mut var);
            let invstd: Vec<f32> = var.iter().map(|v| 1.0 / (v + BN_EPS).sqrt()).collect();
            let mut out = sv.to_vec();
            apply_bn(&mut out, n, &mean, &invstd, &scale, &bias);
            out.iter().zip(&upstream).map(|(&a, &b)| (a * b) as f64).sum()
        };
        let (mut mean, mut var) = (Vec::new(), Vec::new());
        batch_stats(&s, m, n, &mut mean, &mut var);
        let invstd: Vec<f32> = var.iter().map(|v| 1.0 / (v + BN_EPS).sqrt()).collect();
        let mut ds = upstream.clone();
        let (gscale, gbias) = bn_backward(&mut ds, &s, &mean, &invstd, &scale, m, n);
        let h = 1e-3f32;
        for i in [0usize, 4, 7, m * n - 1] {
            let mut sp = s.clone();
            sp[i] += h;
            let mut sm = s.clone();
            sm[i] -= h;
            let fd = ((loss(&sp) - loss(&sm)) / (2.0 * h as f64)) as f32;
            assert!(
                (fd - ds[i]).abs() < 2e-2 * fd.abs().max(1.0),
                "ds[{i}]: analytic {} vs fd {fd}",
                ds[i]
            );
        }
        // dscale / dbias against their definitions
        for j in 0..n {
            let want_bias: f32 = upstream.iter().skip(j).step_by(n).sum();
            assert!((gbias[j] - want_bias).abs() < 1e-4, "gbias[{j}]");
            let want_scale: f32 = (0..m)
                .map(|i| upstream[i * n + j] * (s[i * n + j] - mean[j]) * invstd[j])
                .sum();
            assert!((gscale[j] - want_scale).abs() < 1e-3, "gscale[{j}]");
        }
    }

    #[test]
    fn maxpool_routes_gradient_to_argmax() {
        let x = Tensor::from_fn(&[1, 1, 4, 4], |i| i as f32);
        let mut out = Vec::new();
        let mut idx = Vec::new();
        let dims = maxpool_fwd(x.data(), (1, 1, 4, 4), 2, &mut out, &mut idx);
        assert_eq!(dims, (1, 1, 2, 2));
        assert_eq!(out, vec![5.0, 7.0, 13.0, 15.0]);
        assert_eq!(idx, vec![5, 7, 13, 15]);
    }

    #[test]
    fn nchw_rows_roundtrip() {
        let (n, k, p, q) = (2usize, 3usize, 2usize, 4usize);
        let x: Vec<f32> = (0..n * k * p * q).map(|i| i as f32).collect();
        let mut rows = Vec::new();
        nchw_to_rows_into(&x, n, k, p, q, &mut rows);
        let mut back = Vec::new();
        NativeModel::rows_to_nchw_into(&rows, n, k, p, q, &mut back);
        assert_eq!(x, back);
    }
}
