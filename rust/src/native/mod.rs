//! Native CPU inference engine: replays a model's exact forward topology
//! (exported in the artifact meta) through the host-side sparse engines,
//! with REAL vector-wise column skipping.
//!
//! This is the bridge between the Fig 8(a) layer benchmarks and whole
//! models: the same checkpointed weights that the HLO path evaluates can
//! be run here, where the DSG mask actually removes work instead of
//! multiplying by zero.  Parity with the HLO forward is asserted by
//! `rust/tests/native_parity.rs`.

use crate::coordinator::ModelState;
use crate::drs::projection::TernaryIndex;
use crate::drs::topk;
use crate::runtime::{HostTensor, Meta, Unit};
use crate::sparse;
use crate::tensor::{ops, Tensor};
use anyhow::{bail, Result};
use std::collections::BTreeMap;

const BN_EPS: f32 = 1e-5;

/// Execution mode for the native engine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    /// Full DSG: dimension-reduction search + column skipping.
    Dsg,
    /// Dense baseline (no masking) — the comparison target.
    Dense,
}

/// Per-layer execution record.
#[derive(Clone, Debug)]
pub struct LayerStat {
    pub name: String,
    pub secs: f64,
    pub drs_secs: f64,
    pub density: f64,
}

/// Output of one native forward pass.
pub struct NativeOut {
    pub logits: Tensor,
    pub stats: Vec<LayerStat>,
}

struct ConvParams {
    /// (K, CRS) transposed weight matrix for the skipping VMM
    wt: Tensor,
    ksize: usize,
    stride: usize,
    pad: usize,
}

struct DenseParams {
    /// (d_out, d_in) transposed weights
    wt: Tensor,
    w: Tensor,
    bias: Option<Vec<f32>>,
}

struct BnParams {
    scale: Vec<f32>,
    bias: Vec<f32>,
    mean: Vec<f32>,
    var: Vec<f32>,
}

struct DsgSide {
    ridx: TernaryIndex,
    wp: Tensor,
}

/// A model prepared for native execution (weights transposed and
/// projection index lists prebuilt once).
pub struct NativeModel {
    pub meta: Meta,
    units: Vec<Unit>,
    convs: BTreeMap<String, ConvParams>,
    denses: BTreeMap<String, DenseParams>,
    bns: BTreeMap<String, BnParams>,
    dsg: Vec<DsgSide>,
    double_mask: bool,
    use_bn: bool,
}

fn to_tensor(t: &HostTensor) -> Result<Tensor> {
    Ok(Tensor::new(t.shape(), t.as_f32()?.to_vec()))
}

/// Host-side Wp refresh: fills `state.wps` from the current weights and
/// projection matrices without touching PJRT (the native-only path; the
/// HLO path uses the project artifact instead).
pub fn project_host(meta: &Meta, state: &mut ModelState) -> Result<()> {
    if meta.strategy != "drs" {
        return Ok(());
    }
    let mut wps = Vec::with_capacity(meta.counts.dsg);
    for (li, (&wi, r)) in meta
        .dsg_weight_indices
        .iter()
        .zip(&state.rs)
        .enumerate()
    {
        let w = &state.state[wi];
        let wshape = w.shape().to_vec();
        // conv weights (K, C, r, s) -> (CRS, K); dense already (d, n)
        let wmat = if wshape.len() == 4 {
            let k = wshape[0];
            let crs: usize = wshape[1..].iter().product();
            ops::transpose(&Tensor::new(&[k, crs], w.as_f32()?.to_vec()))
        } else {
            Tensor::new(&wshape, w.as_f32()?.to_vec())
        };
        let rt = to_tensor(r)?;
        let wp = crate::drs::project_weights(&rt, &wmat);
        let spec = &meta.wps[li];
        anyhow::ensure!(
            wp.shape() == &spec.shape[..],
            "host projection shape {:?} != meta {:?}",
            wp.shape(),
            spec.shape
        );
        wps.push(HostTensor::f32(wp.shape(), wp.data().to_vec()));
    }
    state.wps = wps;
    Ok(())
}

impl NativeModel {
    pub fn new(meta: &Meta, state: &ModelState) -> Result<NativeModel> {
        if meta.units.is_empty() {
            bail!("meta {} has no topology — re-run `make artifacts`", meta.name);
        }
        let by_name: BTreeMap<&str, &HostTensor> = meta
            .state
            .iter()
            .zip(&state.state)
            .map(|(spec, t)| (spec.name.as_str(), t))
            .collect();
        let get = |name: String| -> Result<&HostTensor> {
            by_name
                .get(name.as_str())
                .copied()
                .ok_or_else(|| anyhow::anyhow!("missing state leaf {name}"))
        };
        let getv = |name: String| -> Result<Vec<f32>> {
            Ok(get(name)?.as_f32()?.to_vec())
        };

        let mut m = NativeModel {
            meta: meta.clone(),
            units: meta.units.clone(),
            convs: BTreeMap::new(),
            denses: BTreeMap::new(),
            bns: BTreeMap::new(),
            dsg: Vec::new(),
            double_mask: meta.double_mask,
            use_bn: meta.use_bn,
        };

        let add_conv = |m: &mut NativeModel, key: String, wname: String, ksize: usize, stride: usize, pad: usize| -> Result<()> {
            let w = get(wname)?; // (K, C, r, s)
            let k = w.shape()[0];
            let crs: usize = w.shape()[1..].iter().product();
            let wt = Tensor::new(&[k, crs], w.as_f32()?.to_vec());
            m.convs.insert(key, ConvParams { wt, ksize, stride, pad });
            Ok(())
        };
        let add_bn = |m: &mut NativeModel, key: String, path: String| -> Result<()> {
            m.bns.insert(
                key,
                BnParams {
                    scale: getv(format!("bn.{path}.scale"))?,
                    bias: getv(format!("bn.{path}.bias"))?,
                    mean: getv(format!("bn_state.{path}.mean"))?,
                    var: getv(format!("bn_state.{path}.var"))?,
                },
            );
            Ok(())
        };

        for (i, u) in meta.units.clone().iter().enumerate() {
            match u {
                Unit::Dense { .. } => {
                    let w = to_tensor(get(format!("params.{i}.w"))?)?;
                    let wt = ops::transpose(&w);
                    m.denses.insert(i.to_string(), DenseParams { wt, w, bias: None });
                    add_bn(&mut m, i.to_string(), i.to_string())?;
                }
                Unit::Classifier { .. } => {
                    let w = to_tensor(get(format!("params.{i}.w"))?)?;
                    let wt = ops::transpose(&w);
                    let bias = getv(format!("params.{i}.b"))?;
                    m.denses
                        .insert(i.to_string(), DenseParams { wt, w, bias: Some(bias) });
                }
                Unit::Conv { ksize, stride, pad, .. } => {
                    add_conv(&mut m, i.to_string(), format!("params.{i}.w"), *ksize, *stride, *pad)?;
                    add_bn(&mut m, i.to_string(), i.to_string())?;
                }
                Unit::Residual { c_in, c_out, stride } => {
                    add_conv(&mut m, format!("{i}.conv1"), format!("params.{i}.conv1.w"), 3, *stride, 1)?;
                    add_conv(&mut m, format!("{i}.conv2"), format!("params.{i}.conv2.w"), 3, 1, 1)?;
                    if *stride != 1 || c_in != c_out {
                        add_conv(&mut m, format!("{i}.short"), format!("params.{i}.short.w"), 1, *stride, 0)?;
                    }
                    add_bn(&mut m, format!("{i}.bn1"), format!("{i}.bn1"))?;
                    add_bn(&mut m, format!("{i}.bn2"), format!("{i}.bn2"))?;
                }
                Unit::MaxPool { .. } | Unit::GlobalAvgPool | Unit::Flatten => {}
            }
        }

        // DSG side: projection index + projected weights, in dsg order.
        if meta.strategy == "drs" {
            for (r, wp) in state.rs.iter().zip(&state.wps) {
                let rt = to_tensor(r)?;
                m.dsg.push(DsgSide {
                    ridx: TernaryIndex::from_dense(&rt),
                    wp: to_tensor(wp)?,
                });
            }
        }
        Ok(m)
    }

    /// BN in eval mode over rows layout (rows, channels).
    fn bn_rows(&self, rows: &mut Tensor, key: &str) {
        if !self.use_bn {
            return;
        }
        let bn = &self.bns[key];
        let n = rows.shape()[1];
        debug_assert_eq!(bn.scale.len(), n);
        let inv: Vec<f32> = bn
            .var
            .iter()
            .zip(&bn.scale)
            .map(|(v, s)| s / (v + BN_EPS).sqrt())
            .collect();
        let shift: Vec<f32> = bn
            .mean
            .iter()
            .zip(&inv)
            .zip(&bn.bias)
            .map(|((m, i), b)| b - m * i)
            .collect();
        for row in rows.data_mut().chunks_exact_mut(n) {
            for j in 0..n {
                row[j] = row[j] * inv[j] + shift[j];
            }
        }
    }

    /// Shared-threshold mask over virtual activations in rows layout.
    /// `sample0_rows` = how many leading rows belong to sample 0.
    fn mask_for(
        virt: &Tensor,
        gamma: f32,
        sample0_rows: usize,
    ) -> Tensor {
        let n = virt.shape()[1];
        let flat0 = &virt.data()[..sample0_rows * n];
        let size = flat0.len();
        let drop = ((gamma * size as f32).floor() as usize).min(size - 1);
        let t = if drop == 0 {
            f32::NEG_INFINITY
        } else {
            let mut v = flat0.to_vec();
            let (_, nth, _) = v.select_nth_unstable_by(drop, |a, b| a.total_cmp(b));
            *nth
        };
        Tensor::from_fn(virt.shape(), |i| if virt.data()[i] >= t { 1.0 } else { 0.0 })
    }

    /// One DSG (or dense) "matmul layer" over rows: returns masked,
    /// ReLU'd, BN'd, re-masked output rows plus stats.
    ///
    /// `threads = None` runs the single-threaded reference engines;
    /// `Some(t)` routes through `sparse::parallel` with that budget.
    /// Both give bit-exact results for a fixed engine choice, and the
    /// parallel engines are bit-exact across budgets (row split only).
    #[allow(clippy::too_many_arguments)]
    fn rows_layer(
        &self,
        rows: &Tensor,
        wt: &Tensor,
        bn_key: &str,
        dsg_idx: Option<usize>,
        gamma: f32,
        sample0_rows: usize,
        mode: Mode,
        threads: Option<usize>,
        name: &str,
    ) -> (Tensor, LayerStat) {
        let t0 = std::time::Instant::now();
        let (mut y, drs_secs, density, mask) = match (mode, dsg_idx) {
            (Mode::Dsg, Some(di)) if !self.dsg.is_empty() && gamma > 0.0 => {
                let side = &self.dsg[di];
                let td = std::time::Instant::now();
                let xp = match threads {
                    Some(t) => sparse::parallel::project_rows_parallel_with(rows, &side.ridx, t),
                    None => {
                        let m = rows.shape()[0];
                        let k = side.ridx.k;
                        let mut xp = vec![0.0f32; m * k];
                        for i in 0..m {
                            side.ridx.project_row(
                                &rows.data()[i * side.ridx.d..(i + 1) * side.ridx.d],
                                &mut xp[i * k..(i + 1) * k],
                            );
                        }
                        Tensor::new(&[m, k], xp)
                    }
                };
                let virt = match threads {
                    Some(t) => sparse::parallel::matmul_parallel_with(&xp, &side.wp, t),
                    None => ops::matmul_blocked(&xp, &side.wp),
                };
                let mask = Self::mask_for(&virt, gamma, sample0_rows);
                let drs = td.elapsed().as_secs_f64();
                let y = match threads {
                    Some(t) => sparse::parallel::dsg_vmm_parallel_with(rows, wt, &mask, t),
                    None => sparse::dsg_vmm(rows, wt, &mask),
                };
                let density = topk::mask_density(&mask);
                (y, drs, density, Some(mask))
            }
            _ => {
                let y = match threads {
                    Some(t) => sparse::parallel::matmul_parallel_with(rows, &ops::transpose(wt), t),
                    None => ops::matmul_blocked(rows, &ops::transpose(wt)),
                };
                (y, 0.0, 1.0, None)
            }
        };
        ops::relu_inplace(&mut y);
        self.bn_rows(&mut y, bn_key);
        if let (Some(mask), true) = (&mask, self.double_mask) {
            for (v, m) in y.data_mut().iter_mut().zip(mask.data()) {
                *v *= m;
            }
        }
        let stat = LayerStat {
            name: name.to_string(),
            secs: t0.elapsed().as_secs_f64(),
            drs_secs,
            density,
        };
        (y, stat)
    }

    /// rows (N*P*Q, K) -> NCHW tensor.
    fn rows_to_nchw(rows: &Tensor, n: usize, p: usize, q: usize) -> Tensor {
        let k = rows.shape()[1];
        let mut out = vec![0.0f32; n * k * p * q];
        for ni in 0..n {
            for pi in 0..p {
                for qi in 0..q {
                    let r = ((ni * p + pi) * q + qi) * k;
                    for ki in 0..k {
                        out[((ni * k + ki) * p + pi) * q + qi] = rows.data()[r + ki];
                    }
                }
            }
        }
        Tensor::new(&[n, k, p, q], out)
    }

    #[allow(clippy::too_many_arguments)]
    fn conv_unit(
        &self,
        x: &Tensor,
        key: &str,
        bn_key: &str,
        dsg_idx: Option<usize>,
        gamma: f32,
        mode: Mode,
        threads: Option<usize>,
        stats: &mut Vec<LayerStat>,
    ) -> Tensor {
        let cp = &self.convs[key];
        let n = x.shape()[0];
        let (rows, p, q) = ops::im2col(x, cp.ksize, cp.stride, cp.pad);
        let (y, stat) = self.rows_layer(
            &rows,
            &cp.wt,
            bn_key,
            dsg_idx,
            gamma,
            p * q,
            mode,
            threads,
            &format!("conv{key}"),
        );
        stats.push(stat);
        Self::rows_to_nchw(&y, n, p, q)
    }

    /// Shortcut conv (no mask / relu / bn).
    fn plain_conv(&self, x: &Tensor, key: &str, threads: Option<usize>) -> Tensor {
        let cp = &self.convs[key];
        let n = x.shape()[0];
        let (rows, p, q) = ops::im2col(x, cp.ksize, cp.stride, cp.pad);
        let y = match threads {
            Some(t) => sparse::parallel::matmul_parallel_with(&rows, &ops::transpose(&cp.wt), t),
            None => ops::matmul_blocked(&rows, &ops::transpose(&cp.wt)),
        };
        Self::rows_to_nchw(&y, n, p, q)
    }

    /// Full forward pass on a batch (N, input_shape...) using the
    /// single-threaded reference engines.
    pub fn forward(&self, x: &Tensor, gamma: f32, mode: Mode) -> Result<NativeOut> {
        self.forward_impl(x, gamma, mode, None)
    }

    /// Forward pass routed through the multi-threaded engines
    /// (`sparse::parallel`) with an explicit intra-op thread budget —
    /// the serving hot path.  Predictions are bit-exact for any budget,
    /// so a server can divide cores across workers freely.
    pub fn forward_threaded(
        &self,
        x: &Tensor,
        gamma: f32,
        mode: Mode,
        threads: usize,
    ) -> Result<NativeOut> {
        self.forward_impl(x, gamma, mode, Some(threads.max(1)))
    }

    fn forward_impl(
        &self,
        x: &Tensor,
        gamma: f32,
        mode: Mode,
        threads: Option<usize>,
    ) -> Result<NativeOut> {
        let n = x.shape()[0];
        let mut stats = Vec::new();
        let mut dsg_idx = 0usize;
        let mut next_dsg = || {
            let i = dsg_idx;
            dsg_idx += 1;
            Some(i)
        };
        // conv nets carry NCHW; MLPs carry rows (N, D)
        let mut h = x.clone();
        for (i, u) in self.units.iter().enumerate() {
            match u {
                Unit::Dense { .. } => {
                    let dp = &self.denses[&i.to_string()];
                    let (y, stat) = self.rows_layer(
                        &h,
                        &dp.wt,
                        &i.to_string(),
                        next_dsg(),
                        gamma,
                        1,
                        mode,
                        threads,
                        &format!("dense{i}"),
                    );
                    stats.push(stat);
                    h = y;
                }
                Unit::Classifier { d_out, .. } => {
                    let dp = &self.denses[&i.to_string()];
                    let mut y = match threads {
                        Some(t) => sparse::parallel::matmul_parallel_with(&h, &dp.w, t),
                        None => ops::matmul_blocked(&h, &dp.w),
                    };
                    if let Some(b) = &dp.bias {
                        for row in y.data_mut().chunks_exact_mut(*d_out) {
                            for (v, bb) in row.iter_mut().zip(b) {
                                *v += bb;
                            }
                        }
                    }
                    h = y;
                }
                Unit::Conv { .. } => {
                    h = self.conv_unit(
                        &h,
                        &i.to_string(),
                        &i.to_string(),
                        next_dsg(),
                        gamma,
                        mode,
                        threads,
                        &mut stats,
                    );
                }
                Unit::Residual { c_in, c_out, stride } => {
                    let b1 = self.conv_unit(
                        &h,
                        &format!("{i}.conv1"),
                        &format!("{i}.bn1"),
                        next_dsg(),
                        gamma,
                        mode,
                        threads,
                        &mut stats,
                    );
                    let b2 = self.conv_unit(
                        &b1,
                        &format!("{i}.conv2"),
                        &format!("{i}.bn2"),
                        next_dsg(),
                        gamma,
                        mode,
                        threads,
                        &mut stats,
                    );
                    let sc = if *stride != 1 || c_in != c_out {
                        self.plain_conv(&h, &format!("{i}.short"), threads)
                    } else {
                        h.clone()
                    };
                    let mut sum = b2;
                    for (v, s) in sum.data_mut().iter_mut().zip(sc.data()) {
                        *v += s;
                    }
                    h = sum;
                }
                Unit::MaxPool { size } => {
                    h = maxpool(&h, *size);
                }
                Unit::GlobalAvgPool => {
                    h = gap(&h);
                }
                Unit::Flatten => {
                    let d: usize = h.shape()[1..].iter().product();
                    h = h.reshape(&[n, d]);
                }
            }
        }
        if h.shape().len() != 2 || h.shape()[1] != self.meta.classes {
            bail!("native forward produced shape {:?}", h.shape());
        }
        Ok(NativeOut { logits: h, stats })
    }

    /// Classify a batch: argmax per row.
    pub fn predict(&self, x: &Tensor, gamma: f32, mode: Mode) -> Result<Vec<usize>> {
        let out = self.forward(x, gamma, mode)?;
        let c = self.meta.classes;
        Ok(out
            .logits
            .data()
            .chunks_exact(c)
            .map(|row| {
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.total_cmp(b.1))
                    .map(|(j, _)| j)
                    .unwrap_or(0)
            })
            .collect())
    }
}

fn maxpool(x: &Tensor, size: usize) -> Tensor {
    let (n, c, h, w) = (x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]);
    let (ph, pw) = (h / size, w / size);
    let mut out = vec![f32::NEG_INFINITY; n * c * ph * pw];
    for ni in 0..n {
        for ci in 0..c {
            for y in 0..ph {
                for xx in 0..pw {
                    let mut m = f32::NEG_INFINITY;
                    for dy in 0..size {
                        for dx in 0..size {
                            m = m.max(x.at4(ni, ci, y * size + dy, xx * size + dx));
                        }
                    }
                    out[((ni * c + ci) * ph + y) * pw + xx] = m;
                }
            }
        }
    }
    Tensor::new(&[n, c, ph, pw], out)
}

fn gap(x: &Tensor) -> Tensor {
    let (n, c, h, w) = (x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]);
    let mut out = vec![0.0f32; n * c];
    for ni in 0..n {
        for ci in 0..c {
            let mut acc = 0.0f32;
            for y in 0..h {
                for xx in 0..w {
                    acc += x.at4(ni, ci, y, xx);
                }
            }
            out[ni * c + ci] = acc / (h * w) as f32;
        }
    }
    Tensor::new(&[n, c], out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maxpool_known() {
        let x = Tensor::from_fn(&[1, 1, 4, 4], |i| i as f32);
        let y = maxpool(&x, 2);
        assert_eq!(y.shape(), &[1, 1, 2, 2]);
        assert_eq!(y.data(), &[5.0, 7.0, 13.0, 15.0]);
    }

    #[test]
    fn gap_known() {
        let x = Tensor::from_fn(&[1, 2, 2, 2], |i| i as f32);
        let y = gap(&x);
        assert_eq!(y.shape(), &[1, 2]);
        assert_eq!(y.data(), &[1.5, 5.5]);
    }

    #[test]
    fn rows_to_nchw_roundtrip() {
        // rows layout is (N*P*Q, K) with (n, p, q) major order
        let n = 2;
        let (p, q, k) = (2, 3, 4);
        let rows = Tensor::from_fn(&[n * p * q, k], |i| i as f32);
        let x = NativeModel::rows_to_nchw(&rows, n, p, q);
        assert_eq!(x.shape(), &[n, k, p, q]);
        // element (n=1, k=2, p=0, q=1): row = (1*2+0)*3+1 = 7, col 2 -> 7*4+2
        assert_eq!(x.at4(1, 2, 0, 1), (7 * 4 + 2) as f32);
    }

    #[test]
    fn mask_for_density() {
        let mut rng = crate::util::Pcg32::seeded(3);
        let virt = Tensor::new(&[10, 50], rng.normal_vec(500, 1.0));
        let m = NativeModel::mask_for(&virt, 0.8, 2); // sample 0 = 2 rows
        let d0: f32 = m.data()[..100].iter().sum::<f32>() / 100.0;
        assert!((d0 - 0.2).abs() < 0.011);
        let m0 = NativeModel::mask_for(&virt, 0.0, 2);
        assert_eq!(m0.data().iter().sum::<f32>(), 500.0);
    }
}
