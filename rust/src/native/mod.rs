//! Native CPU inference engine: replays a model's exact forward topology
//! (exported in the artifact meta) through the host-side sparse engines,
//! with REAL vector-wise column skipping.
//!
//! This is the bridge between the Fig 8(a) layer benchmarks and whole
//! models: the same checkpointed weights that the HLO path evaluates can
//! be run here, where the DSG mask actually removes work instead of
//! multiplying by zero.  Parity with the HLO forward is asserted by
//! `rust/tests/native_parity.rs`.
//!
//! The request hot path avoids per-layer buffer allocation in steady
//! state: every forward runs inside a [`ForwardWorkspace`] whose
//! buffers (im2col rows, projection output, virtual activations,
//! compact [`RowMask`], layer outputs) are resized in place and reused
//! across layers AND across requests.  [`NativeModel`] keeps an internal [`WorkspacePool`]
//! so concurrent serve workers each end up owning one workspace; parallel
//! engine dispatch goes through the persistent
//! [`crate::sparse::pool::WorkerPool`] instead of spawning threads.

pub mod train;
pub mod zoo;

use crate::coordinator::ModelState;
use crate::drs::projection::TernaryIndex;
use crate::drs::topk::{pool_threshold, structured_k, RowMask, SelectionMode};
use crate::runtime::{HostTensor, Meta, Unit};
use crate::sparse;
use crate::tensor::{ops, Tensor};
use anyhow::{bail, Result};
use std::collections::BTreeMap;
use std::sync::Mutex;

const BN_EPS: f32 = 1e-5;

/// Execution mode for the native engine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    /// Full DSG: dimension-reduction search + column skipping.
    Dsg,
    /// Dense baseline (no masking) — the comparison target.
    Dense,
}

/// Per-layer execution record.
#[derive(Clone, Debug)]
pub struct LayerStat {
    pub name: String,
    pub secs: f64,
    pub drs_secs: f64,
    pub density: f64,
    /// Multiply-adds the kernels actually executed (compound dispatch
    /// counts what it ran; dense branches count the full GEMM).
    pub realized_madds: u64,
    /// Dense-equivalent baseline m * d * n for the same shape.
    pub dense_madds: u64,
}

/// Output of one native forward pass.
pub struct NativeOut {
    pub logits: Tensor,
    pub stats: Vec<LayerStat>,
}

struct ConvParams {
    /// (K, CRS) transposed weight matrix for the skipping VMM.
    wt: Tensor,
    /// (CRS, K) untransposed weights for the dense GEMM branch —
    /// precomputed at model build instead of re-transposed per call.
    w: Tensor,
    ksize: usize,
    stride: usize,
    pad: usize,
}

struct DenseParams {
    /// (d_out, d_in) transposed weights
    wt: Tensor,
    w: Tensor,
    bias: Option<Vec<f32>>,
}

/// Eval-mode BN folded to a per-channel affine at model build:
/// y = x * inv + shift, with inv = scale / sqrt(var + eps) and
/// shift = bias - mean * inv.  Same arithmetic the per-call version
/// performed, computed once.
struct BnParams {
    inv: Vec<f32>,
    shift: Vec<f32>,
}

impl BnParams {
    fn new(scale: Vec<f32>, bias: Vec<f32>, mean: Vec<f32>, var: Vec<f32>) -> BnParams {
        let inv: Vec<f32> = var
            .iter()
            .zip(&scale)
            .map(|(v, s)| s / (v + BN_EPS).sqrt())
            .collect();
        let shift: Vec<f32> = mean
            .iter()
            .zip(&inv)
            .zip(&bias)
            .map(|((m, i), b)| b - m * i)
            .collect();
        BnParams { inv, shift }
    }
}

struct DsgSide {
    ridx: TernaryIndex,
    wp: Tensor,
}

/// Per-layer scratch shared by every matmul layer of a forward pass.
#[derive(Default)]
pub(crate) struct LayerScratch {
    /// Projected rows (m, k).
    pub(crate) xp: Vec<f32>,
    /// Virtual activations (m, n).
    pub(crate) virt: Vec<f32>,
    /// Threshold-selection candidate pool.
    pub(crate) thr: Vec<f32>,
    /// Per-row (score, index) pairs for structured top-k selection.
    pub(crate) pairs: Vec<(f32, u32)>,
    /// Compact selection mask.
    pub(crate) mask: RowMask,
}

/// Reusable buffers for forward passes.  Every buffer is resized in
/// place per layer (capacity is kept), so after the first forward a
/// workspace performs no per-layer heap allocation — across layers and
/// across requests.
#[derive(Default)]
pub struct ForwardWorkspace {
    pub(crate) scratch: LayerScratch,
    /// im2col rows.
    pub(crate) rows: Vec<f32>,
    /// rows_layer output (and generic rows-shaped temp).
    pub(crate) y: Vec<f32>,
    /// Current activation carried between units.
    pub(crate) h: Vec<f32>,
    /// Unit-output / residual temps.
    pub(crate) t1: Vec<f32>,
    pub(crate) t2: Vec<f32>,
    pub(crate) t3: Vec<f32>,
}

impl ForwardWorkspace {
    pub fn new() -> ForwardWorkspace {
        ForwardWorkspace::default()
    }
}

/// Checkout/return pool of [`ForwardWorkspace`]s.  Sized by peak
/// concurrency: with N serve workers hitting the same model, at most N
/// workspaces are ever created and each is reused across requests.
#[derive(Default)]
pub struct WorkspacePool {
    free: Mutex<Vec<ForwardWorkspace>>,
}

impl WorkspacePool {
    pub fn new() -> WorkspacePool {
        WorkspacePool::default()
    }

    /// Pop a cached workspace (or build a fresh one on first use).
    pub fn take(&self) -> ForwardWorkspace {
        self.free.lock().unwrap().pop().unwrap_or_default()
    }

    /// Return a workspace for reuse.
    pub fn put(&self, ws: ForwardWorkspace) {
        self.free.lock().unwrap().push(ws);
    }
}

/// Activation shape carried between units (data lives in `ws.h`).
/// Shared with the training engine's taped forward ([`train`]).
#[derive(Clone, Copy, Debug)]
pub(crate) enum Carry {
    /// (rows, features) — MLP layout.
    Rows(usize, usize),
    /// (n, c, h, w) — conv layout.
    Nchw(usize, usize, usize, usize),
}

/// A model prepared for native execution (weights transposed and
/// projection index lists prebuilt once).
pub struct NativeModel {
    pub meta: Meta,
    units: Vec<Unit>,
    convs: BTreeMap<String, ConvParams>,
    denses: BTreeMap<String, DenseParams>,
    bns: BTreeMap<String, BnParams>,
    dsg: Vec<DsgSide>,
    double_mask: bool,
    use_bn: bool,
    selection: SelectionMode,
    kernels: sparse::parallel::SparseKernels,
    ws_pool: WorkspacePool,
}

pub(crate) fn to_tensor(t: &HostTensor) -> Result<Tensor> {
    Ok(Tensor::new(t.shape(), t.as_f32()?.to_vec()))
}

/// Host-side Wp refresh: fills `state.wps` from the current weights and
/// projection matrices without touching PJRT (the native-only path; the
/// HLO path uses the project artifact instead).
pub fn project_host(meta: &Meta, state: &mut ModelState) -> Result<()> {
    if meta.strategy != "drs" {
        return Ok(());
    }
    let mut wps = Vec::with_capacity(meta.counts.dsg);
    for (li, (&wi, r)) in meta
        .dsg_weight_indices
        .iter()
        .zip(&state.rs)
        .enumerate()
    {
        let w = &state.state[wi];
        let wshape = w.shape().to_vec();
        // conv weights (K, C, r, s) -> (CRS, K); dense already (d, n)
        let wmat = if wshape.len() == 4 {
            let k = wshape[0];
            let crs: usize = wshape[1..].iter().product();
            ops::transpose(&Tensor::new(&[k, crs], w.as_f32()?.to_vec()))
        } else {
            Tensor::new(&wshape, w.as_f32()?.to_vec())
        };
        let rt = to_tensor(r)?;
        // index built once per layer refresh, shared with the projection
        // (project_weights would rebuild it internally)
        let ridx = TernaryIndex::from_dense(&rt);
        let wp = crate::drs::project_weights_idx(&ridx, &wmat);
        let spec = &meta.wps[li];
        anyhow::ensure!(
            wp.shape() == &spec.shape[..],
            "host projection shape {:?} != meta {:?}",
            wp.shape(),
            spec.shape
        );
        wps.push(HostTensor::f32(wp.shape(), wp.data().to_vec()));
    }
    state.wps = wps;
    Ok(())
}

impl NativeModel {
    pub fn new(meta: &Meta, state: &ModelState) -> Result<NativeModel> {
        if meta.units.is_empty() {
            bail!("meta {} has no topology — re-run `make artifacts`", meta.name);
        }
        let by_name: BTreeMap<&str, &HostTensor> = meta
            .state
            .iter()
            .zip(&state.state)
            .map(|(spec, t)| (spec.name.as_str(), t))
            .collect();
        let get = |name: String| -> Result<&HostTensor> {
            by_name
                .get(name.as_str())
                .copied()
                .ok_or_else(|| anyhow::anyhow!("missing state leaf {name}"))
        };
        let getv = |name: String| -> Result<Vec<f32>> {
            Ok(get(name)?.as_f32()?.to_vec())
        };

        let mut m = NativeModel {
            meta: meta.clone(),
            units: meta.units.clone(),
            convs: BTreeMap::new(),
            denses: BTreeMap::new(),
            bns: BTreeMap::new(),
            dsg: Vec::new(),
            double_mask: meta.double_mask,
            use_bn: meta.use_bn,
            selection: SelectionMode::default(),
            kernels: sparse::parallel::SparseKernels::default(),
            ws_pool: WorkspacePool::new(),
        };

        let add_conv = |m: &mut NativeModel, key: String, wname: String, ksize: usize, stride: usize, pad: usize| -> Result<()> {
            let w = get(wname)?; // (K, C, r, s)
            let k = w.shape()[0];
            let crs: usize = w.shape()[1..].iter().product();
            let wt = Tensor::new(&[k, crs], w.as_f32()?.to_vec());
            // untransposed (CRS, K) stored once — the dense branch and
            // plain_conv used to recompute this transpose on every call
            let wmat = ops::transpose(&wt);
            m.convs.insert(key, ConvParams { wt, w: wmat, ksize, stride, pad });
            Ok(())
        };
        let add_bn = |m: &mut NativeModel, key: String, path: String| -> Result<()> {
            m.bns.insert(
                key,
                BnParams::new(
                    getv(format!("bn.{path}.scale"))?,
                    getv(format!("bn.{path}.bias"))?,
                    getv(format!("bn_state.{path}.mean"))?,
                    getv(format!("bn_state.{path}.var"))?,
                ),
            );
            Ok(())
        };

        for (i, u) in meta.units.clone().iter().enumerate() {
            match u {
                Unit::Dense { .. } => {
                    let w = to_tensor(get(format!("params.{i}.w"))?)?;
                    let wt = ops::transpose(&w);
                    m.denses.insert(i.to_string(), DenseParams { wt, w, bias: None });
                    add_bn(&mut m, i.to_string(), i.to_string())?;
                }
                Unit::Classifier { .. } => {
                    let w = to_tensor(get(format!("params.{i}.w"))?)?;
                    let wt = ops::transpose(&w);
                    let bias = getv(format!("params.{i}.b"))?;
                    m.denses
                        .insert(i.to_string(), DenseParams { wt, w, bias: Some(bias) });
                }
                Unit::Conv { ksize, stride, pad, .. } => {
                    add_conv(&mut m, i.to_string(), format!("params.{i}.w"), *ksize, *stride, *pad)?;
                    add_bn(&mut m, i.to_string(), i.to_string())?;
                }
                Unit::Residual { c_in, c_out, stride } => {
                    add_conv(&mut m, format!("{i}.conv1"), format!("params.{i}.conv1.w"), 3, *stride, 1)?;
                    add_conv(&mut m, format!("{i}.conv2"), format!("params.{i}.conv2.w"), 3, 1, 1)?;
                    if *stride != 1 || c_in != c_out {
                        add_conv(&mut m, format!("{i}.short"), format!("params.{i}.short.w"), 1, *stride, 0)?;
                    }
                    add_bn(&mut m, format!("{i}.bn1"), format!("{i}.bn1"))?;
                    add_bn(&mut m, format!("{i}.bn2"), format!("{i}.bn2"))?;
                }
                Unit::MaxPool { .. } | Unit::GlobalAvgPool | Unit::Flatten => {}
            }
        }

        // DSG side: projection index + projected weights, in dsg order.
        if meta.strategy == "drs" {
            for (r, wp) in state.rs.iter().zip(&state.wps) {
                let rt = to_tensor(r)?;
                m.dsg.push(DsgSide {
                    ridx: TernaryIndex::from_dense(&rt),
                    wp: to_tensor(wp)?,
                });
            }
        }
        Ok(m)
    }

    /// Selection-mode override (builder style; default unstructured).
    pub fn with_selection(mut self, selection: SelectionMode) -> NativeModel {
        self.selection = selection;
        self
    }

    /// Kernel-mode override (builder style; default scalar compound).
    /// Inference only consults the kernel TABLE behind the mode —
    /// [`sparse::parallel::SparseKernels::Simd`] swaps in the
    /// runtime-detected SIMD primitives (ULP-relaxed forward dots);
    /// every other mode serves on the bit-exact scalar table.
    pub fn with_kernels(mut self, kernels: sparse::parallel::SparseKernels) -> NativeModel {
        self.kernels = kernels;
        self
    }

    /// BN in eval mode over rows layout (rows, channels), prefolded
    /// affine applied in place.
    fn bn_rows(&self, rows: &mut [f32], n: usize, key: &str) {
        if !self.use_bn {
            return;
        }
        let bn = &self.bns[key];
        debug_assert_eq!(bn.inv.len(), n);
        for row in rows.chunks_exact_mut(n) {
            for j in 0..n {
                row[j] = row[j] * bn.inv[j] + bn.shift[j];
            }
        }
    }

    /// Shared-threshold selection over virtual activations in rows
    /// layout, written into the workspace's compact mask.
    /// `sample0_rows` = how many leading rows belong to sample 0.  The
    /// threshold candidate pool is copied into `thr_scratch` (capacity
    /// reused) instead of a fresh Vec per layer call.
    pub(crate) fn mask_for(
        virt: &[f32],
        width: usize,
        gamma: f32,
        sample0_rows: usize,
        thr_scratch: &mut Vec<f32>,
        mask: &mut RowMask,
    ) {
        // pool_threshold degrades a zero-element candidate pool (empty
        // batch or zero-width layer) to keep-all
        let size = sample0_rows * width;
        let t = pool_threshold(&virt[..size], gamma, thr_scratch);
        let rows = if width == 0 { 0 } else { virt.len() / width };
        mask.fill_from_threshold(virt, rows, width, t);
    }

    /// Selection-mode dispatch: unstructured shared-threshold CSR mask
    /// vs structured per-row top-k in the packed `FixedK` layout.  The
    /// structured arm ranks every row independently (no sample-0 pool),
    /// with `k` derived from gamma so both modes keep the same fraction
    /// at matched gamma.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn mask_select(
        selection: SelectionMode,
        virt: &[f32],
        width: usize,
        gamma: f32,
        sample0_rows: usize,
        thr_scratch: &mut Vec<f32>,
        pairs_scratch: &mut Vec<(f32, u32)>,
        mask: &mut RowMask,
    ) {
        match selection {
            SelectionMode::Unstructured => {
                Self::mask_for(virt, width, gamma, sample0_rows, thr_scratch, mask);
            }
            SelectionMode::Structured { blocked } => {
                let rows = if width == 0 { 0 } else { virt.len() / width };
                let k = structured_k(width, gamma, blocked);
                mask.fill_topk(virt, rows, width, k, pairs_scratch);
            }
        }
    }

    /// Zero the non-selected entries of rows-layout `y` (the double-mask
    /// re-application after BN).  Walks each row's ascending selected
    /// list once — equivalent to the old dense elementwise multiply.
    pub(crate) fn apply_mask_rows(y: &mut [f32], n: usize, mask: &RowMask) {
        if mask.is_full() {
            return;
        }
        for i in 0..mask.rows() {
            let row = &mut y[i * n..(i + 1) * n];
            let sel = mask.row(i);
            let mut next = 0usize;
            for (j, v) in row.iter_mut().enumerate() {
                if next < sel.len() && sel[next] as usize == j {
                    next += 1;
                } else {
                    *v = 0.0;
                }
            }
        }
    }

    /// One DSG (or dense) "matmul layer" over rows: masked, ReLU'd,
    /// BN'd, re-masked output rows written into `out`, stats returned
    /// along with the estimated nonzero density of the output — the
    /// next layer's compound-dispatch hint.
    ///
    /// `in_density` is THIS layer's hint: the measured mask density of
    /// the producing layer (adjusted for ReLU/BN), 1.0 for raw inputs.
    /// The masked VMM routes through the compound kernels, which exploit
    /// the input-side zeros when the hint (and the per-row gathered nnz)
    /// says they pay — every dispatch branch is bit-identical, so the
    /// hint affects time, never bits.
    ///
    /// `threads = None` runs the single-threaded reference engines;
    /// `Some(t)` routes through the pool-backed `sparse::parallel` with
    /// that budget.  Both give bit-exact results for a fixed engine
    /// choice, and the parallel engines are bit-exact across budgets
    /// (row split only).
    #[allow(clippy::too_many_arguments)]
    fn rows_layer_ws(
        &self,
        x: &[f32],
        m: usize,
        d: usize,
        wt: &Tensor,
        w: &Tensor,
        bn_key: &str,
        dsg_idx: Option<usize>,
        gamma: f32,
        sample0_rows: usize,
        mode: Mode,
        threads: Option<usize>,
        in_density: f32,
        name: &str,
        scratch: &mut LayerScratch,
        out: &mut Vec<f32>,
    ) -> (LayerStat, f32) {
        let t0 = std::time::Instant::now();
        let n = wt.shape()[0];
        debug_assert_eq!(x.len(), m * d);
        let dense_madds = (m * d * n) as u64;
        // every kernel below fully writes its output range, so the
        // buffer only needs the right LENGTH — no clear(): resize
        // zero-fills just the grown tail, not the whole prefix
        out.resize(m * n, 0.0);
        let (drs_secs, density, masked, realized) = match (mode, dsg_idx) {
            (Mode::Dsg, Some(di)) if !self.dsg.is_empty() && gamma > 0.0 => {
                let side = &self.dsg[di];
                let td = std::time::Instant::now();
                let k = side.ridx.k;
                scratch.xp.resize(m * k, 0.0);
                match threads {
                    Some(t) => sparse::parallel::project_rows_parallel_into(
                        x, m, &side.ridx, t, &mut scratch.xp,
                    ),
                    None => sparse::parallel::project_chunk(&side.ridx, x, 0, m, &mut scratch.xp),
                }
                scratch.virt.resize(m * n, 0.0);
                match threads {
                    Some(t) => sparse::parallel::matmul_parallel_into(
                        &scratch.xp, m, k, side.wp.data(), n, t, &mut scratch.virt,
                    ),
                    None => ops::matmul_blocked_into(
                        &scratch.xp, m, k, side.wp.data(), n, &mut scratch.virt,
                    ),
                }
                Self::mask_select(
                    self.selection,
                    &scratch.virt,
                    n,
                    gamma,
                    sample0_rows,
                    &mut scratch.thr,
                    &mut scratch.pairs,
                    &mut scratch.mask,
                );
                let drs = td.elapsed().as_secs_f64();
                let realized = sparse::parallel::dsg_vmm_compound_parallel_into_kt(
                    self.kernels.table(),
                    x,
                    m,
                    d,
                    wt.data(),
                    n,
                    &scratch.mask,
                    in_density,
                    threads.unwrap_or(1),
                    out,
                );
                (drs, scratch.mask.density(), true, realized)
            }
            _ => {
                match threads {
                    Some(t) => sparse::parallel::matmul_parallel_into(x, m, d, w.data(), n, t, out),
                    None => ops::matmul_blocked_into(x, m, d, w.data(), n, out),
                }
                // the dense GEMM's opportunistic zero-skip is not
                // counted: this IS the dense baseline
                (0.0, 1.0, false, dense_madds)
            }
        };
        ops::relu_slice(out);
        self.bn_rows(out, n, bn_key);
        if masked && self.double_mask {
            Self::apply_mask_rows(out, n, &scratch.mask);
        }
        // next layer's dispatch hint from the measured mask density
        // (`density` is already 1.0 on the unmasked dense arm) — the
        // rule is shared with the training and synth engines
        let out_density = sparse::parallel::density_hint_after_layer(
            density as f32,
            self.use_bn,
            self.double_mask && masked,
        );
        let stat = LayerStat {
            name: name.to_string(),
            secs: t0.elapsed().as_secs_f64(),
            drs_secs,
            density,
            realized_madds: realized,
            dense_madds,
        };
        (stat, out_density)
    }

    /// rows (N*P*Q, K) -> NCHW into a reused buffer.
    pub(crate) fn rows_to_nchw_into(rows: &[f32], n: usize, k: usize, p: usize, q: usize, out: &mut Vec<f32>) {
        debug_assert_eq!(rows.len(), n * p * q * k);
        out.resize(n * k * p * q, 0.0); // fully overwritten below
        for ni in 0..n {
            for pi in 0..p {
                for qi in 0..q {
                    let r = ((ni * p + pi) * q + qi) * k;
                    for ki in 0..k {
                        out[((ni * k + ki) * p + pi) * q + qi] = rows[r + ki];
                    }
                }
            }
        }
    }

    /// rows (N*P*Q, K) -> NCHW tensor (test helper).
    #[cfg(test)]
    fn rows_to_nchw(rows: &Tensor, n: usize, p: usize, q: usize) -> Tensor {
        let k = rows.shape()[1];
        let mut out = Vec::new();
        Self::rows_to_nchw_into(rows.data(), n, k, p, q, &mut out);
        Tensor::new(&[n, k, p, q], out)
    }

    /// One conv unit: im2col into `rows_buf`, masked layer into `y_buf`,
    /// NCHW result into `out`.  Returns the output dims and the next
    /// layer's density hint (im2col and the rows->NCHW flip replicate
    /// values, which preserves the zero fraction the hint estimates).
    #[allow(clippy::too_many_arguments)]
    fn conv_unit_ws(
        &self,
        x: &[f32],
        dims: (usize, usize, usize, usize),
        key: &str,
        bn_key: &str,
        dsg_idx: Option<usize>,
        gamma: f32,
        mode: Mode,
        threads: Option<usize>,
        in_density: f32,
        scratch: &mut LayerScratch,
        rows_buf: &mut Vec<f32>,
        y_buf: &mut Vec<f32>,
        out: &mut Vec<f32>,
        stats: &mut Vec<LayerStat>,
    ) -> ((usize, usize, usize, usize), f32) {
        let cp = &self.convs[key];
        let (n, c, h, w) = dims;
        let (p, q) =
            ops::im2col_slice_into(x, n, c, h, w, cp.ksize, cp.stride, cp.pad, rows_buf);
        let d = c * cp.ksize * cp.ksize;
        let kout = cp.wt.shape()[0];
        let (stat, out_density) = self.rows_layer_ws(
            rows_buf,
            n * p * q,
            d,
            &cp.wt,
            &cp.w,
            bn_key,
            dsg_idx,
            gamma,
            p * q,
            mode,
            threads,
            in_density,
            &format!("conv{key}"),
            scratch,
            y_buf,
        );
        stats.push(stat);
        Self::rows_to_nchw_into(y_buf, n, kout, p, q, out);
        ((n, kout, p, q), out_density)
    }

    /// Shortcut conv (no mask / relu / bn) into `out`.
    #[allow(clippy::too_many_arguments)]
    fn plain_conv_ws(
        &self,
        x: &[f32],
        dims: (usize, usize, usize, usize),
        key: &str,
        threads: Option<usize>,
        rows_buf: &mut Vec<f32>,
        y_buf: &mut Vec<f32>,
        out: &mut Vec<f32>,
    ) {
        let cp = &self.convs[key];
        let (n, c, h, w) = dims;
        let (p, q) =
            ops::im2col_slice_into(x, n, c, h, w, cp.ksize, cp.stride, cp.pad, rows_buf);
        let d = c * cp.ksize * cp.ksize;
        let kout = cp.wt.shape()[0];
        y_buf.resize(n * p * q * kout, 0.0); // matmul kernel zero-fills
        match threads {
            Some(t) => sparse::parallel::matmul_parallel_into(
                rows_buf,
                n * p * q,
                d,
                cp.w.data(),
                kout,
                t,
                y_buf,
            ),
            None => ops::matmul_blocked_into(rows_buf, n * p * q, d, cp.w.data(), kout, y_buf),
        }
        Self::rows_to_nchw_into(y_buf, n, kout, p, q, out);
    }

    fn maxpool_into(
        xd: &[f32],
        dims: (usize, usize, usize, usize),
        size: usize,
        out: &mut Vec<f32>,
    ) -> (usize, usize, usize, usize) {
        let (n, c, h, w) = dims;
        let (ph, pw) = (h / size, w / size);
        out.resize(n * c * ph * pw, 0.0); // fully overwritten below
        for ni in 0..n {
            for ci in 0..c {
                for y in 0..ph {
                    for xx in 0..pw {
                        let mut m = f32::NEG_INFINITY;
                        for dy in 0..size {
                            for dx in 0..size {
                                m = m.max(
                                    xd[((ni * c + ci) * h + y * size + dy) * w + xx * size + dx],
                                );
                            }
                        }
                        out[((ni * c + ci) * ph + y) * pw + xx] = m;
                    }
                }
            }
        }
        (n, c, ph, pw)
    }

    fn gap_into(
        xd: &[f32],
        dims: (usize, usize, usize, usize),
        out: &mut Vec<f32>,
    ) -> (usize, usize) {
        let (n, c, h, w) = dims;
        out.resize(n * c, 0.0); // fully overwritten below
        for ni in 0..n {
            for ci in 0..c {
                let mut acc = 0.0f32;
                for y in 0..h {
                    for xx in 0..w {
                        acc += xd[((ni * c + ci) * h + y) * w + xx];
                    }
                }
                out[ni * c + ci] = acc / (h * w) as f32;
            }
        }
        (n, c)
    }

    /// Full forward pass on a batch (N, input_shape...) using the
    /// single-threaded reference engines, on a pooled workspace.
    pub fn forward(&self, x: &Tensor, gamma: f32, mode: Mode) -> Result<NativeOut> {
        let mut ws = self.ws_pool.take();
        let r = self.forward_impl(x, gamma, mode, None, &mut ws);
        self.ws_pool.put(ws);
        r
    }

    /// Forward pass routed through the pool-backed multi-threaded
    /// engines (`sparse::parallel`) with an explicit intra-op thread
    /// budget — the serving hot path.  Predictions are bit-exact for any
    /// budget, so a server can divide cores across workers freely.
    pub fn forward_threaded(
        &self,
        x: &Tensor,
        gamma: f32,
        mode: Mode,
        threads: usize,
    ) -> Result<NativeOut> {
        let mut ws = self.ws_pool.take();
        let r = self.forward_impl(x, gamma, mode, Some(threads.max(1)), &mut ws);
        self.ws_pool.put(ws);
        r
    }

    /// Forward pass on a caller-owned workspace (`threads = None` for
    /// the single-threaded reference engines).  Reusing the same
    /// workspace across calls is the allocation-free steady state.
    pub fn forward_with_workspace(
        &self,
        x: &Tensor,
        gamma: f32,
        mode: Mode,
        threads: Option<usize>,
        ws: &mut ForwardWorkspace,
    ) -> Result<NativeOut> {
        self.forward_impl(x, gamma, mode, threads, ws)
    }

    fn forward_impl(
        &self,
        x: &Tensor,
        gamma: f32,
        mode: Mode,
        threads: Option<usize>,
        ws: &mut ForwardWorkspace,
    ) -> Result<NativeOut> {
        let n = x.shape()[0];
        let mut stats = Vec::new();
        let mut dsg_idx = 0usize;
        let mut next_dsg = || {
            let i = dsg_idx;
            dsg_idx += 1;
            Some(i)
        };
        // conv nets carry NCHW; MLPs carry rows (N, D)
        ws.h.clear();
        ws.h.extend_from_slice(x.data());
        let mut carry = match x.shape().len() {
            2 => Carry::Rows(n, x.shape()[1]),
            4 => Carry::Nchw(n, x.shape()[1], x.shape()[2], x.shape()[3]),
            r => bail!("native forward input rank {r} unsupported"),
        };
        // compound-dispatch hint: estimated nonzero fraction of the
        // activation entering the next matmul layer (raw input = dense)
        let mut hint = 1.0f32;
        for (i, u) in self.units.iter().enumerate() {
            match u {
                Unit::Dense { .. } => {
                    let Carry::Rows(m, d) = carry else {
                        bail!("dense unit {i} on non-rows activation")
                    };
                    let dp = &self.denses[&i.to_string()];
                    let (stat, out_density) = self.rows_layer_ws(
                        &ws.h,
                        m,
                        d,
                        &dp.wt,
                        &dp.w,
                        &i.to_string(),
                        next_dsg(),
                        gamma,
                        1,
                        mode,
                        threads,
                        hint,
                        &format!("dense{i}"),
                        &mut ws.scratch,
                        &mut ws.y,
                    );
                    hint = out_density;
                    stats.push(stat);
                    std::mem::swap(&mut ws.h, &mut ws.y);
                    carry = Carry::Rows(m, dp.wt.shape()[0]);
                }
                Unit::Classifier { d_out, .. } => {
                    let Carry::Rows(m, d) = carry else {
                        bail!("classifier unit {i} on non-rows activation")
                    };
                    let dp = &self.denses[&i.to_string()];
                    ws.y.resize(m * d_out, 0.0); // matmul kernel zero-fills
                    match threads {
                        Some(t) => sparse::parallel::matmul_parallel_into(
                            &ws.h, m, d, dp.w.data(), *d_out, t, &mut ws.y,
                        ),
                        None => ops::matmul_blocked_into(
                            &ws.h, m, d, dp.w.data(), *d_out, &mut ws.y,
                        ),
                    }
                    if let Some(b) = &dp.bias {
                        for row in ws.y.chunks_exact_mut(*d_out) {
                            for (v, bb) in row.iter_mut().zip(b) {
                                *v += bb;
                            }
                        }
                    }
                    std::mem::swap(&mut ws.h, &mut ws.y);
                    carry = Carry::Rows(m, *d_out);
                }
                Unit::Conv { .. } => {
                    let Carry::Nchw(nn, c, hh, www) = carry else {
                        bail!("conv unit {i} on non-NCHW activation")
                    };
                    let (dims, out_density) = self.conv_unit_ws(
                        &ws.h,
                        (nn, c, hh, www),
                        &i.to_string(),
                        &i.to_string(),
                        next_dsg(),
                        gamma,
                        mode,
                        threads,
                        hint,
                        &mut ws.scratch,
                        &mut ws.rows,
                        &mut ws.y,
                        &mut ws.t1,
                        &mut stats,
                    );
                    hint = out_density;
                    std::mem::swap(&mut ws.h, &mut ws.t1);
                    carry = Carry::Nchw(dims.0, dims.1, dims.2, dims.3);
                }
                Unit::Residual { c_in, c_out, stride } => {
                    let Carry::Nchw(nn, c, hh, www) = carry else {
                        bail!("residual unit {i} on non-NCHW activation")
                    };
                    let (d1, h1_density) = self.conv_unit_ws(
                        &ws.h,
                        (nn, c, hh, www),
                        &format!("{i}.conv1"),
                        &format!("{i}.bn1"),
                        next_dsg(),
                        gamma,
                        mode,
                        threads,
                        hint,
                        &mut ws.scratch,
                        &mut ws.rows,
                        &mut ws.y,
                        &mut ws.t1,
                        &mut stats,
                    );
                    let (d2, _) = self.conv_unit_ws(
                        &ws.t1,
                        d1,
                        &format!("{i}.conv2"),
                        &format!("{i}.bn2"),
                        next_dsg(),
                        gamma,
                        mode,
                        threads,
                        h1_density,
                        &mut ws.scratch,
                        &mut ws.rows,
                        &mut ws.y,
                        &mut ws.t2,
                        &mut stats,
                    );
                    // the residual sum merges two streams (masked main
                    // path + dense shortcut): treat the output as dense
                    hint = 1.0;
                    if *stride != 1 || c_in != c_out {
                        self.plain_conv_ws(
                            &ws.h,
                            (nn, c, hh, www),
                            &format!("{i}.short"),
                            threads,
                            &mut ws.rows,
                            &mut ws.y,
                            &mut ws.t3,
                        );
                        for (v, s) in ws.t2.iter_mut().zip(&ws.t3) {
                            *v += s;
                        }
                    } else {
                        for (v, s) in ws.t2.iter_mut().zip(&ws.h) {
                            *v += s;
                        }
                    }
                    std::mem::swap(&mut ws.h, &mut ws.t2);
                    carry = Carry::Nchw(d2.0, d2.1, d2.2, d2.3);
                }
                Unit::MaxPool { size } => {
                    let Carry::Nchw(nn, c, hh, www) = carry else {
                        bail!("maxpool unit {i} on non-NCHW activation")
                    };
                    let dims = Self::maxpool_into(&ws.h, (nn, c, hh, www), *size, &mut ws.t1);
                    // max over a size^2 window is zero only when the
                    // whole window is: density 1 - (1 - p)^(size^2)
                    hint = 1.0 - (1.0 - hint).powi((*size * *size) as i32);
                    std::mem::swap(&mut ws.h, &mut ws.t1);
                    carry = Carry::Nchw(dims.0, dims.1, dims.2, dims.3);
                }
                Unit::GlobalAvgPool => {
                    let Carry::Nchw(nn, c, hh, www) = carry else {
                        bail!("gap unit {i} on non-NCHW activation")
                    };
                    let (rn, rc) = Self::gap_into(&ws.h, (nn, c, hh, www), &mut ws.t1);
                    hint = 1.0; // plane averages are essentially dense
                    std::mem::swap(&mut ws.h, &mut ws.t1);
                    carry = Carry::Rows(rn, rc);
                }
                Unit::Flatten => {
                    // NCHW row-major == rows (N, C*H*W): shape-only change
                    carry = match carry {
                        Carry::Rows(m, d) => Carry::Rows(m, d),
                        Carry::Nchw(nn, c, hh, www) => Carry::Rows(nn, c * hh * www),
                    };
                }
            }
        }
        let Carry::Rows(m, c) = carry else {
            bail!("native forward ended on an NCHW activation")
        };
        if m != n || c != self.meta.classes {
            bail!("native forward produced shape [{m}, {c}]");
        }
        Ok(NativeOut { logits: Tensor::new(&[m, c], ws.h[..m * c].to_vec()), stats })
    }

    /// Classify a batch: argmax per row.
    pub fn predict(&self, x: &Tensor, gamma: f32, mode: Mode) -> Result<Vec<usize>> {
        let out = self.forward(x, gamma, mode)?;
        let c = self.meta.classes;
        Ok(out
            .logits
            .data()
            .chunks_exact(c)
            .map(|row| {
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.total_cmp(b.1))
                    .map(|(j, _)| j)
                    .unwrap_or(0)
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn maxpool(x: &Tensor, size: usize) -> Tensor {
        let dims = (x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]);
        let mut out = Vec::new();
        let (n, c, p, q) = NativeModel::maxpool_into(x.data(), dims, size, &mut out);
        Tensor::new(&[n, c, p, q], out)
    }

    fn gap(x: &Tensor) -> Tensor {
        let dims = (x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]);
        let mut out = Vec::new();
        let (n, c) = NativeModel::gap_into(x.data(), dims, &mut out);
        Tensor::new(&[n, c], out)
    }

    #[test]
    fn maxpool_known() {
        let x = Tensor::from_fn(&[1, 1, 4, 4], |i| i as f32);
        let y = maxpool(&x, 2);
        assert_eq!(y.shape(), &[1, 1, 2, 2]);
        assert_eq!(y.data(), &[5.0, 7.0, 13.0, 15.0]);
    }

    #[test]
    fn gap_known() {
        let x = Tensor::from_fn(&[1, 2, 2, 2], |i| i as f32);
        let y = gap(&x);
        assert_eq!(y.shape(), &[1, 2]);
        assert_eq!(y.data(), &[1.5, 5.5]);
    }

    #[test]
    fn rows_to_nchw_roundtrip() {
        // rows layout is (N*P*Q, K) with (n, p, q) major order
        let n = 2;
        let (p, q, k) = (2, 3, 4);
        let rows = Tensor::from_fn(&[n * p * q, k], |i| i as f32);
        let x = NativeModel::rows_to_nchw(&rows, n, p, q);
        assert_eq!(x.shape(), &[n, k, p, q]);
        // element (n=1, k=2, p=0, q=1): row = (1*2+0)*3+1 = 7, col 2 -> 7*4+2
        assert_eq!(x.at4(1, 2, 0, 1), (7 * 4 + 2) as f32);
    }

    #[test]
    fn mask_for_density() {
        let mut rng = crate::util::Pcg32::seeded(3);
        let virt = Tensor::new(&[10, 50], rng.normal_vec(500, 1.0));
        let mut scratch = Vec::new();
        let mut m = RowMask::new();
        NativeModel::mask_for(virt.data(), 50, 0.8, 2, &mut scratch, &mut m); // sample 0 = 2 rows
        let d0 = (m.row(0).len() + m.row(1).len()) as f64 / 100.0;
        assert!((d0 - 0.2).abs() < 0.011);
        NativeModel::mask_for(virt.data(), 50, 0.0, 2, &mut scratch, &mut m);
        assert!(m.is_full());
        assert_eq!(m.selected(), 500);
    }

    #[test]
    fn mask_for_zero_size_keeps_all() {
        let mut scratch = Vec::new();
        let mut m = RowMask::new();
        // zero-width layer: no candidates, no panic, empty keep-all mask
        NativeModel::mask_for(&[], 0, 0.8, 4, &mut scratch, &mut m);
        assert_eq!(m.rows(), 0);
        assert_eq!(m.selected(), 0);
        // zero sample-0 rows (empty batch): keep everything that exists
        let virt = vec![1.0f32, -1.0, 2.0, -2.0];
        NativeModel::mask_for(&virt, 2, 0.8, 0, &mut scratch, &mut m);
        assert!(m.is_full());
        assert_eq!(m.selected(), 4);
    }

    #[test]
    fn mask_select_dispatches_by_mode() {
        let mut rng = crate::util::Pcg32::seeded(4);
        let virt = Tensor::new(&[6, 40], rng.normal_vec(240, 1.0));
        let mut thr = Vec::new();
        let mut pairs = Vec::new();
        let mut m = RowMask::new();
        // unstructured arm == mask_for, bit for bit
        NativeModel::mask_select(
            SelectionMode::Unstructured, virt.data(), 40, 0.7, 2, &mut thr, &mut pairs, &mut m,
        );
        let mut want = RowMask::new();
        NativeModel::mask_for(virt.data(), 40, 0.7, 2, &mut thr, &mut want);
        assert_eq!(m, want);
        // structured arm: packed constant fan-in, same keep rate rule
        NativeModel::mask_select(
            SelectionMode::Structured { blocked: false },
            virt.data(), 40, 0.7, 2, &mut thr, &mut pairs, &mut m,
        );
        let k = structured_k(40, 0.7, false);
        assert_eq!(m.fixed_k(), Some(k));
        for i in 0..6 {
            assert_eq!(m.row(i).len(), k);
        }
        // blocked arm: k rounded up to the 4-lane contract
        NativeModel::mask_select(
            SelectionMode::Structured { blocked: true },
            virt.data(), 40, 0.7, 2, &mut thr, &mut pairs, &mut m,
        );
        assert_eq!(m.fixed_k(), Some(structured_k(40, 0.7, true)));
        assert_eq!(m.fixed_k().unwrap() % 4, 0);
        // gamma 0 in structured mode keeps all — same as unstructured
        NativeModel::mask_select(
            SelectionMode::Structured { blocked: false },
            virt.data(), 40, 0.0, 2, &mut thr, &mut pairs, &mut m,
        );
        assert!(m.is_full());
        assert_eq!(m.selected(), 240);
    }

    #[test]
    fn apply_mask_rows_zeroes_unselected() {
        let virt = Tensor::new(&[2, 4], vec![1.0, -1.0, 2.0, -2.0, -3.0, 3.0, -4.0, 4.0]);
        let mask = RowMask::from_threshold(&virt, 0.0);
        let mut y = vec![9.0f32; 8];
        NativeModel::apply_mask_rows(&mut y, 4, &mask);
        assert_eq!(y, vec![9.0, 0.0, 9.0, 0.0, 0.0, 9.0, 0.0, 9.0]);
    }

    #[test]
    fn workspace_pool_recycles() {
        let pool = WorkspacePool::new();
        let mut ws = pool.take();
        ws.h.resize(1024, 1.0);
        let cap = ws.h.capacity();
        pool.put(ws);
        let ws2 = pool.take();
        assert!(ws2.h.capacity() >= cap, "buffer capacity must survive the pool");
    }
}
