//! Host-side model zoo: synthesize a full artifact [`Meta`] (topology,
//! state-leaf layout, init specs, DSG projection shapes) for the paper's
//! model variants WITHOUT python, XLA, or an artifacts directory.
//!
//! This is the rust mirror of `python/compile/models.py` +
//! `aot.py::export_variant`'s meta emission: leaf names, group order
//! (params ++ vel ++ bn ++ vbn ++ bn_state), sorted-dict-key ordering
//! inside a unit ("b" < "w", "bias" < "scale", "mean" < "var",
//! "conv1" < "conv2" < "short"), He/zeros/ones/ternary init recipes, and
//! the JLL projection dimension per DSG layer are all reproduced, so
//! [`crate::coordinator::ModelState::init`] and the native engines
//! consume a synthesized meta exactly like a loaded one.  The only
//! difference is `files`/`kept` being empty: there are no HLO artifacts
//! behind it, which is the point — `dsg train --engine native` runs end
//! to end on a box with nothing but the rust toolchain.

use crate::costmodel::jll;
use crate::runtime::{Counts, DType, DsgLayer, Init, LeafSpec, Meta, Unit};
use anyhow::{bail, Result};

/// A zoo model description (the rust twin of `models.py::Model`).
#[derive(Clone, Debug)]
pub struct ModelSpec {
    pub name: String,
    /// canonical zoo name (what `base_model` records)
    pub base_model: String,
    /// (D,) for MLPs, (C, H, W) for conv nets
    pub input_shape: Vec<usize>,
    pub classes: usize,
    pub batch: usize,
    pub units: Vec<Unit>,
    /// "drs" or "dense" (oracle/random need the HLO path)
    pub strategy: String,
    pub eps: f64,
    pub double_mask: bool,
    pub use_bn: bool,
}

impl ModelSpec {
    fn base(name: &str, input_shape: &[usize], batch: usize, units: Vec<Unit>) -> ModelSpec {
        ModelSpec {
            name: name.to_string(),
            base_model: name.to_string(),
            input_shape: input_shape.to_vec(),
            classes: 10,
            batch,
            units,
            strategy: "drs".into(),
            eps: 0.5,
            double_mask: true,
            use_bn: true,
        }
    }

    /// 784-hidden-hidden-10 MLP (FASHION-like).
    pub fn mlp(batch: usize, hidden: usize) -> ModelSpec {
        Self::base(
            "mlp",
            &[784],
            batch,
            vec![
                Unit::Dense { d_in: 784, d_out: hidden },
                Unit::Dense { d_in: hidden, d_out: hidden },
                Unit::Classifier { d_in: hidden, d_out: 10 },
            ],
        )
    }

    /// An arbitrary DSG MLP from layer widths (tests / experiments):
    /// `dims = [input, h1, h2, ...]` plus a classifier to `classes`.
    pub fn custom_mlp(name: &str, dims: &[usize], classes: usize, batch: usize) -> ModelSpec {
        assert!(dims.len() >= 2, "need input + at least one hidden width");
        let mut units = Vec::new();
        for w in dims.windows(2) {
            units.push(Unit::Dense { d_in: w[0], d_out: w[1] });
        }
        units.push(Unit::Classifier { d_in: *dims.last().unwrap(), d_out: classes });
        let mut s = Self::base(name, &dims[..1], batch, units);
        s.classes = classes;
        s
    }

    /// LeNet-5 (FASHION-like).
    pub fn lenet(batch: usize) -> ModelSpec {
        Self::base(
            "lenet",
            &[1, 28, 28],
            batch,
            vec![
                Unit::Conv { c_in: 1, c_out: 6, ksize: 5, stride: 1, pad: 2 },
                Unit::MaxPool { size: 2 },
                Unit::Conv { c_in: 6, c_out: 16, ksize: 5, stride: 1, pad: 0 },
                Unit::MaxPool { size: 2 },
                Unit::Flatten,
                Unit::Dense { d_in: 16 * 5 * 5, d_out: 120 },
                Unit::Dense { d_in: 120, d_out: 84 },
                Unit::Classifier { d_in: 84, d_out: 10 },
            ],
        )
    }

    /// VGG-8 at width `w` (CIFAR-like).
    pub fn vgg8(batch: usize, w: usize, name: &str) -> ModelSpec {
        let conv = |c_in: usize, c_out: usize| Unit::Conv { c_in, c_out, ksize: 3, stride: 1, pad: 1 };
        Self::base(
            name,
            &[3, 32, 32],
            batch,
            vec![
                conv(3, w),
                conv(w, w),
                Unit::MaxPool { size: 2 },
                conv(w, 2 * w),
                conv(2 * w, 2 * w),
                Unit::MaxPool { size: 2 },
                conv(2 * w, 4 * w),
                conv(4 * w, 4 * w),
                Unit::MaxPool { size: 2 },
                Unit::Flatten,
                Unit::Dense { d_in: 4 * w * 4 * 4, d_out: 8 * w },
                Unit::Classifier { d_in: 8 * w, d_out: 10 },
            ],
        )
    }

    /// The paper's custom resnet8 variant at width `w` (CIFAR-like).
    pub fn resnet8(batch: usize, w: usize, name: &str) -> ModelSpec {
        Self::base(
            name,
            &[3, 32, 32],
            batch,
            vec![
                Unit::Conv { c_in: 3, c_out: w, ksize: 3, stride: 1, pad: 1 },
                Unit::Residual { c_in: w, c_out: w, stride: 1 },
                Unit::Residual { c_in: w, c_out: 2 * w, stride: 2 },
                Unit::Residual { c_in: 2 * w, c_out: 4 * w, stride: 2 },
                Unit::GlobalAvgPool,
                Unit::Dense { d_in: 4 * w, d_out: 64 },
                Unit::Classifier { d_in: 64, d_out: 10 },
            ],
        )
    }

    /// Switch to the dense (no-masking) strategy, renamed like the
    /// exported `<model>_dense` variants.
    pub fn dense_variant(mut self) -> ModelSpec {
        self.name = format!("{}_dense", self.name);
        self.strategy = "dense".into();
        self
    }
}

/// Look up a zoo model by (possibly `_dense`-suffixed) variant name,
/// mirroring the exported artifact names.
pub fn spec_for(variant: &str) -> Result<ModelSpec> {
    let (base, dense) = match variant.strip_suffix("_dense") {
        Some(b) => (b, true),
        None => (variant, false),
    };
    let spec = match base {
        "mlp" => ModelSpec::mlp(64, 256),
        "lenet" => ModelSpec::lenet(32),
        "vgg8" => ModelSpec::vgg8(16, 32, "vgg8"),
        "vgg8s" => ModelSpec::vgg8(16, 16, "vgg8s"),
        "resnet8" => ModelSpec::resnet8(16, 16, "resnet8"),
        "wrn8_2" => ModelSpec::resnet8(16, 32, "wrn8_2"),
        other => bail!(
            "unknown native model {other:?} (have mlp, lenet, vgg8, vgg8s, resnet8, wrn8_2, \
             each also as <name>_dense)"
        ),
    };
    Ok(if dense { spec.dense_variant() } else { spec })
}

fn leaf(name: String, shape: &[usize], init: Init) -> LeafSpec {
    LeafSpec { name, shape: shape.to_vec(), dtype: DType::F32, init }
}

fn he(name: String, shape: &[usize]) -> LeafSpec {
    // conv (K, C, r, s): fan_in = C*r*s; dense (d_in, d_out): fan_in = d_in
    let fan_in = if shape.len() == 4 { shape[1] * shape[2] * shape[3] } else { shape[0] };
    leaf(name, shape, Init::HeNormal { fan_in })
}

/// The (path, k, d_in, n_out) description of every DSG-maskable layer,
/// in buffer order (`models.py::projection_shapes`).
pub fn dsg_shapes(spec: &ModelSpec) -> Vec<DsgLayer> {
    let mut out = Vec::new();
    let mut push = |path: String, d_in: usize, n_out: usize, eps: f64| {
        out.push(DsgLayer { path, k: jll::projection_dim(eps, n_out, d_in), d_in, n_out });
    };
    for (i, u) in spec.units.iter().enumerate() {
        match u {
            Unit::Dense { d_in, d_out } => push(format!("u{i}"), *d_in, *d_out, spec.eps),
            Unit::Conv { c_in, c_out, ksize, .. } => {
                push(format!("u{i}"), c_in * ksize * ksize, *c_out, spec.eps)
            }
            Unit::Residual { c_in, c_out, .. } => {
                push(format!("u{i}.conv1"), c_in * 9, *c_out, spec.eps);
                push(format!("u{i}.conv2"), c_out * 9, *c_out, spec.eps);
            }
            _ => {}
        }
    }
    out
}

/// Synthesize the full artifact meta for a zoo spec (see module docs).
pub fn synth_meta(spec: &ModelSpec) -> Result<Meta> {
    if !matches!(spec.strategy.as_str(), "drs" | "dense") {
        bail!(
            "native meta synthesis supports strategies drs/dense, not {:?} \
             (oracle/random need the HLO artifacts)",
            spec.strategy
        );
    }
    // --- params group (and its zero-init velocity twin) ----------------
    let mut params: Vec<LeafSpec> = Vec::new();
    let mut bn: Vec<LeafSpec> = Vec::new();
    let mut bn_state: Vec<LeafSpec> = Vec::new();
    let push_bn = |bn: &mut Vec<LeafSpec>, bn_state: &mut Vec<LeafSpec>, path: String, c: usize| {
        // sorted dict keys: bias < scale, mean < var
        bn.push(leaf(format!("bn.{path}.bias"), &[c], Init::Zeros));
        bn.push(leaf(format!("bn.{path}.scale"), &[c], Init::Ones));
        bn_state.push(leaf(format!("bn_state.{path}.mean"), &[c], Init::Zeros));
        bn_state.push(leaf(format!("bn_state.{path}.var"), &[c], Init::Ones));
    };
    for (i, u) in spec.units.iter().enumerate() {
        match u {
            Unit::Dense { d_in, d_out } => {
                params.push(he(format!("params.{i}.w"), &[*d_in, *d_out]));
                push_bn(&mut bn, &mut bn_state, i.to_string(), *d_out);
            }
            Unit::Classifier { d_in, d_out } => {
                // sorted dict keys: b < w
                params.push(leaf(format!("params.{i}.b"), &[*d_out], Init::Zeros));
                params.push(he(format!("params.{i}.w"), &[*d_in, *d_out]));
            }
            Unit::Conv { c_in, c_out, ksize, .. } => {
                params.push(he(format!("params.{i}.w"), &[*c_out, *c_in, *ksize, *ksize]));
                push_bn(&mut bn, &mut bn_state, i.to_string(), *c_out);
            }
            Unit::Residual { c_in, c_out, stride } => {
                params.push(he(format!("params.{i}.conv1.w"), &[*c_out, *c_in, 3, 3]));
                params.push(he(format!("params.{i}.conv2.w"), &[*c_out, *c_out, 3, 3]));
                if *stride != 1 || c_in != c_out {
                    params.push(he(format!("params.{i}.short.w"), &[*c_out, *c_in, 1, 1]));
                }
                push_bn(&mut bn, &mut bn_state, format!("{i}.bn1"), *c_out);
                push_bn(&mut bn, &mut bn_state, format!("{i}.bn2"), *c_out);
            }
            Unit::MaxPool { .. } | Unit::GlobalAvgPool | Unit::Flatten => {}
        }
    }
    let vel: Vec<LeafSpec> = params
        .iter()
        .map(|p| leaf(p.name.replacen("params.", "vel.", 1), &p.shape, Init::Zeros))
        .collect();
    let vbn: Vec<LeafSpec> = bn
        .iter()
        .map(|p| leaf(p.name.replacen("bn.", "vbn.", 1), &p.shape, Init::Zeros))
        .collect();

    // --- DSG side -------------------------------------------------------
    let layers = dsg_shapes(spec);
    let is_drs = spec.strategy == "drs";
    let (wps, rs): (Vec<LeafSpec>, Vec<LeafSpec>) = if is_drs {
        layers
            .iter()
            .enumerate()
            .map(|(li, l)| {
                (
                    leaf(format!("wp.{li}"), &[l.k, l.n_out], Init::Zeros),
                    leaf(format!("r.{li}"), &[l.k, l.d_in], Init::Ternary { s: 3 }),
                )
            })
            .unzip()
    } else {
        (Vec::new(), Vec::new())
    };

    let counts = Counts {
        params: params.len(),
        vel: vel.len(),
        bn: bn.len(),
        vbn: vbn.len(),
        bn_state: bn_state.len(),
        wps: wps.len(),
        rs: rs.len(),
        dsg: layers.len(),
    };
    let state: Vec<LeafSpec> = params
        .into_iter()
        .chain(vel)
        .chain(bn)
        .chain(vbn)
        .chain(bn_state)
        .collect();
    let dsg_weight_indices: Vec<usize> = if is_drs {
        layers
            .iter()
            .map(|l| {
                // "u3" -> "params.3.w"; "u5.conv1" -> "params.5.conv1.w"
                let wname = format!("params.{}.w", &l.path[1..]);
                state
                    .iter()
                    .position(|s| s.name == wname)
                    .ok_or_else(|| anyhow::anyhow!("no state leaf {wname}"))
            })
            .collect::<Result<_>>()?
    } else {
        Vec::new()
    };

    Ok(Meta {
        name: spec.name.clone(),
        base_model: spec.base_model.clone(),
        batch: spec.batch,
        input_shape: spec.input_shape.clone(),
        classes: spec.classes,
        strategy: spec.strategy.clone(),
        eps: spec.eps,
        double_mask: spec.double_mask,
        use_bn: spec.use_bn,
        files: Default::default(),
        kept: Default::default(),
        counts,
        state,
        wps,
        rs,
        dsg_weight_indices,
        dsg_layers: if is_drs { layers } else { Vec::new() },
        units: spec.units.clone(),
        dir: std::path::PathBuf::from("<synthesized>"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::ModelState;

    #[test]
    fn mlp_meta_matches_exported_layout() {
        // mirrors the shape facts asserted against the real artifact meta
        // in runtime::meta tests: 20 state leaves, 2 dsg layers, batch 64
        let m = synth_meta(&spec_for("mlp").unwrap()).unwrap();
        assert_eq!(m.batch, 64);
        assert_eq!(m.counts.dsg, 2);
        assert_eq!(m.state.len(), 20);
        assert!(m.state[0].name.starts_with("params."));
        assert!(m.state[19].name.starts_with("bn_state."));
        assert_eq!(m.counts.params, 4); // 2 dense w + classifier b + w
        // classifier leaves in sorted-dict order: b before w
        assert_eq!(m.state[2].name, "params.2.b");
        assert_eq!(m.state[3].name, "params.2.w");
        assert_eq!(m.dsg_weight_indices, vec![0, 1]);
        assert_eq!(m.wps[0].shape, vec![m.dsg_layers[0].k, 256]);
        assert_eq!(m.rs[0].shape, vec![m.dsg_layers[0].k, 784]);
        assert!(!m.has_file("train"));
    }

    #[test]
    fn dense_variant_has_no_projections() {
        let m = synth_meta(&spec_for("mlp_dense").unwrap()).unwrap();
        assert_eq!(m.strategy, "dense");
        assert_eq!(m.counts.wps, 0);
        assert_eq!(m.counts.rs, 0);
        assert_eq!(m.counts.dsg, 2); // densities still reported per layer
        assert!(m.dsg_weight_indices.is_empty());
    }

    #[test]
    fn state_init_consumes_synth_meta() {
        for name in ["mlp", "lenet", "resnet8"] {
            let m = synth_meta(&spec_for(name).unwrap()).unwrap();
            let s = ModelState::init(&m, 7);
            assert_eq!(s.state.len(), m.state.len(), "{name}");
            assert_eq!(s.wps.len(), m.counts.wps, "{name}");
            assert_eq!(s.rs.len(), m.counts.rs, "{name}");
            assert_eq!(s.dsg_weights(&m).len(), m.dsg_weight_indices.len());
        }
    }

    #[test]
    fn residual_shortcut_leaves_only_when_needed() {
        let m = synth_meta(&spec_for("resnet8").unwrap()).unwrap();
        let names: Vec<&str> = m.state.iter().map(|l| l.name.as_str()).collect();
        // residual u1 is stride-1 same-width: no shortcut weight
        assert!(!names.contains(&"params.1.short.w"));
        // u2 and u3 change width/stride: shortcut present
        assert!(names.contains(&"params.2.short.w"));
        assert!(names.contains(&"params.3.short.w"));
        // stem conv + 3 residuals x 2 + head dense (classifier unmasked)
        assert_eq!(m.counts.dsg, 8);
    }

    #[test]
    fn unknown_model_is_clean_error() {
        assert!(spec_for("vgg99").is_err());
        // oracle/random strategies are HLO-only
        let mut s = spec_for("mlp").unwrap();
        s.strategy = "oracle".into();
        assert!(synth_meta(&s).is_err());
    }
}
