//! Dense f32 tensor substrate for the host-side engines.
//!
//! This is NOT a general autodiff tensor — the differentiable compute
//! lives in the AOT HLO artifacts.  This type backs the CPU sparse
//! execution engine (Fig 8), the data pipeline, ZVC, and the DRS host
//! implementation.  Row-major (C order), matching the artifact buffers.

pub mod ops;

/// Row-major dense f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: &[usize], data: Vec<f32>) -> Self {
        let n: usize = shape.iter().product();
        assert_eq!(n, data.len(), "shape {shape:?} != data len {}", data.len());
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn zeros(shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn full(shape: &[usize], v: f32) -> Self {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![v; n] }
    }

    pub fn from_fn(shape: &[usize], mut f: impl FnMut(usize) -> f32) -> Self {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: (0..n).map(|i| f(i)).collect() }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// 2-D accessor (rows, cols).
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.shape.len(), 2);
        self.data[i * self.shape[1] + j]
    }

    pub fn set2(&mut self, i: usize, j: usize, v: f32) {
        debug_assert_eq!(self.shape.len(), 2);
        self.data[i * self.shape[1] + j] = v;
    }

    /// 4-D accessor (n, c, h, w).
    pub fn at4(&self, n: usize, c: usize, h: usize, w: usize) -> f32 {
        debug_assert_eq!(self.shape.len(), 4);
        let (_, cc, hh, ww) = (self.shape[0], self.shape[1], self.shape[2], self.shape[3]);
        self.data[((n * cc + c) * hh + h) * ww + w]
    }

    pub fn reshape(&self, shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        assert_eq!(n, self.data.len(), "reshape {shape:?} from {:?}", self.shape);
        Tensor { shape: shape.to_vec(), data: self.data.clone() }
    }

    /// Fraction of exactly-zero entries (the activation-sparsity metric).
    pub fn zero_fraction(&self) -> f64 {
        if self.data.is_empty() {
            return 0.0;
        }
        let z = self.data.iter().filter(|&&x| x == 0.0).count();
        z as f64 / self.data.len() as f64
    }

    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    pub fn allclose(&self, other: &Tensor, atol: f32, rtol: f32) -> bool {
        if self.shape != other.shape {
            return false;
        }
        self.data
            .iter()
            .zip(&other.data)
            .all(|(a, b)| (a - b).abs() <= atol + rtol * b.abs())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_index() {
        let t = Tensor::new(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.at2(0, 2), 3.0);
        assert_eq!(t.at2(1, 0), 4.0);
        assert_eq!(t.shape(), &[2, 3]);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        Tensor::new(&[2, 2], vec![1.0; 3]);
    }

    #[test]
    fn at4_layout_matches_c_order() {
        let t = Tensor::from_fn(&[2, 3, 4, 5], |i| i as f32);
        assert_eq!(t.at4(0, 0, 0, 0), 0.0);
        assert_eq!(t.at4(0, 0, 0, 4), 4.0);
        assert_eq!(t.at4(0, 0, 1, 0), 5.0);
        assert_eq!(t.at4(0, 1, 0, 0), 20.0);
        assert_eq!(t.at4(1, 0, 0, 0), 60.0);
    }

    #[test]
    fn zero_fraction() {
        let t = Tensor::new(&[4], vec![0.0, 1.0, 0.0, 2.0]);
        assert_eq!(t.zero_fraction(), 0.5);
        assert_eq!(Tensor::zeros(&[3]).zero_fraction(), 1.0);
    }

    #[test]
    fn allclose_and_diff() {
        let a = Tensor::new(&[2], vec![1.0, 2.0]);
        let b = Tensor::new(&[2], vec![1.0, 2.0 + 1e-6]);
        assert!(a.allclose(&b, 1e-5, 1e-5));
        assert!(a.max_abs_diff(&b) < 2e-6);
        let c = Tensor::new(&[2], vec![1.0, 3.0]);
        assert!(!a.allclose(&c, 1e-5, 1e-5));
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::from_fn(&[2, 6], |i| i as f32);
        let r = t.reshape(&[3, 4]);
        assert_eq!(r.at2(1, 1), 5.0);
    }
}
