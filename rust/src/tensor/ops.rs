//! Host-side tensor ops: matmul (naive + blocked), transpose, im2col,
//! relu.  The blocked matmul is the Fig-8 GEMM baseline; the sparse
//! engines in `crate::sparse` compare against it.

use super::Tensor;

/// Naive triple-loop matmul — the correctness oracle for the optimized
/// paths. a: (m, k), b: (k, n) -> (m, n).
pub fn matmul_naive(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (k2, n) = (b.shape()[0], b.shape()[1]);
    assert_eq!(k, k2, "inner dims {k} vs {k2}");
    let mut out = vec![0.0f32; m * n];
    let ad = a.data();
    let bd = b.data();
    for i in 0..m {
        for p in 0..k {
            let av = ad[i * k + p];
            if av == 0.0 {
                continue;
            }
            let brow = &bd[p * n..(p + 1) * n];
            let orow = &mut out[i * n..(i + 1) * n];
            for j in 0..n {
                orow[j] += av * brow[j];
            }
        }
    }
    Tensor::new(&[m, n], out)
}

/// Cache-blocked matmul with 4x4 register blocking — the "GEMM" baseline
/// of Fig 8(a) (stands in for MKL sgemm; see the substitutions note in docs/ARCHITECTURE.md).
///
/// §Perf iteration L3-1: processing 4 rows of `a` per inner sweep reuses
/// each loaded `b` row four times, ~1.9x over the previous saxpy loop.
pub fn matmul_blocked(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (k2, n) = (b.shape()[0], b.shape()[1]);
    assert_eq!(k, k2, "inner dims {k} vs {k2}");
    let mut out = vec![0.0f32; m * n];
    matmul_blocked_into(a.data(), m, k, b.data(), n, &mut out);
    Tensor::new(&[m, n], out)
}

/// [`matmul_blocked`] into a caller-owned buffer (len m*n), slice form —
/// the allocation-free workspace path.  Zeroes `out` first.
pub fn matmul_blocked_into(ad: &[f32], m: usize, k: usize, bd: &[f32], n: usize, out: &mut [f32]) {
    const KC: usize = 256; // depth per block (L1-resident b panel rows)
    debug_assert_eq!(ad.len(), m * k);
    debug_assert_eq!(bd.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    out.fill(0.0);
    for p0 in (0..k).step_by(KC) {
        let p1 = (p0 + KC).min(k);
        let mut i = 0;
        // 4-row micro-kernel: each b row load feeds 4 accum rows
        while i + 4 <= m {
            let (a0, a1, a2, a3) = (
                &ad[i * k..(i + 1) * k],
                &ad[(i + 1) * k..(i + 2) * k],
                &ad[(i + 2) * k..(i + 3) * k],
                &ad[(i + 3) * k..(i + 4) * k],
            );
            // split out into four disjoint row slices
            let (o01, o23) = out[i * n..(i + 4) * n].split_at_mut(2 * n);
            let (o0, o1) = o01.split_at_mut(n);
            let (o2, o3) = o23.split_at_mut(n);
            for p in p0..p1 {
                let (v0, v1, v2, v3) = (a0[p], a1[p], a2[p], a3[p]);
                if v0 == 0.0 && v1 == 0.0 && v2 == 0.0 && v3 == 0.0 {
                    continue;
                }
                let brow = &bd[p * n..(p + 1) * n];
                for j in 0..n {
                    let bv = brow[j];
                    o0[j] += v0 * bv;
                    o1[j] += v1 * bv;
                    o2[j] += v2 * bv;
                    o3[j] += v3 * bv;
                }
            }
            i += 4;
        }
        // remainder rows
        for ii in i..m {
            let arow = &ad[ii * k..(ii + 1) * k];
            let orow = &mut out[ii * n..(ii + 1) * n];
            for p in p0..p1 {
                let av = arow[p];
                if av == 0.0 {
                    continue;
                }
                let brow = &bd[p * n..(p + 1) * n];
                for j in 0..n {
                    orow[j] += av * brow[j];
                }
            }
        }
    }
}

/// Transpose a row-major (m, n) slice into a caller-owned buffer,
/// resized to n*m (capacity reused) — the trainer's per-step
/// weight-layout flips.
pub fn transpose_into(src: &[f32], m: usize, n: usize, out: &mut Vec<f32>) {
    debug_assert_eq!(src.len(), m * n);
    out.resize(m * n, 0.0); // fully overwritten below
    for i in 0..m {
        for j in 0..n {
            out[j * m + i] = src[i * n + j];
        }
    }
}

/// Transpose a 2-D tensor.
pub fn transpose(a: &Tensor) -> Tensor {
    let (m, n) = (a.shape()[0], a.shape()[1]);
    let ad = a.data();
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        for j in 0..n {
            out[j * m + i] = ad[i * n + j];
        }
    }
    Tensor::new(&[n, m], out)
}

/// ReLU in place.
pub fn relu_inplace(t: &mut Tensor) {
    relu_slice(t.data_mut());
}

/// ReLU over a raw slice (the workspace hot path).
pub fn relu_slice(xs: &mut [f32]) {
    for v in xs {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// im2col: x (N, C, H, W) -> rows (N*P*Q, C*KH*KW) for conv-as-VMM
/// (paper Fig 3a->3b).  `pad` is symmetric zero padding.
pub fn im2col(
    x: &Tensor,
    ksize: usize,
    stride: usize,
    pad: usize,
) -> (Tensor, usize, usize) {
    let (n, c, h, w) = (
        x.shape()[0],
        x.shape()[1],
        x.shape()[2],
        x.shape()[3],
    );
    let mut out = Vec::new();
    let (p, q) = im2col_slice_into(x.data(), n, c, h, w, ksize, stride, pad, &mut out);
    (Tensor::new(&[n * p * q, c * ksize * ksize], out), p, q)
}

/// [`im2col`] from a raw NCHW slice into a caller-owned buffer that is
/// resized (reusing capacity) and fully overwritten — the allocation-free
/// workspace path.  Returns (p, q).
#[allow(clippy::too_many_arguments)]
pub fn im2col_slice_into(
    xd: &[f32],
    n: usize,
    c: usize,
    h: usize,
    w: usize,
    ksize: usize,
    stride: usize,
    pad: usize,
    out: &mut Vec<f32>,
) -> (usize, usize) {
    debug_assert_eq!(xd.len(), n * c * h * w);
    let p = (h + 2 * pad - ksize) / stride + 1;
    let q = (w + 2 * pad - ksize) / stride + 1;
    let d = c * ksize * ksize;
    out.resize(n * p * q * d, 0.0); // every position written below
    for ni in 0..n {
        for pi in 0..p {
            for qi in 0..q {
                let row = ((ni * p + pi) * q + qi) * d;
                let mut col = 0;
                for ci in 0..c {
                    for kh in 0..ksize {
                        let hy = (pi * stride + kh) as isize - pad as isize;
                        for kw in 0..ksize {
                            let wx = (qi * stride + kw) as isize - pad as isize;
                            let v = if hy >= 0
                                && (hy as usize) < h
                                && wx >= 0
                                && (wx as usize) < w
                            {
                                xd[((ni * c + ci) * h + hy as usize) * w + wx as usize]
                            } else {
                                0.0
                            };
                            out[row + col] = v;
                            col += 1;
                        }
                    }
                }
            }
        }
    }
    (p, q)
}

/// Adjoint of [`im2col_slice_into`]: scatter-add rows (N*P*Q, C*KH*KW)
/// back onto the NCHW image they were gathered from — the conv backward
/// pass's gradient-to-input step.  Positions gathered by several sliding
/// windows accumulate every window's contribution; padded positions are
/// dropped.  `out` is resized to n*c*h*w and zeroed first.
#[allow(clippy::too_many_arguments)]
pub fn col2im_slice_into(
    rows: &[f32],
    n: usize,
    c: usize,
    h: usize,
    w: usize,
    ksize: usize,
    stride: usize,
    pad: usize,
    out: &mut Vec<f32>,
) {
    let p = (h + 2 * pad - ksize) / stride + 1;
    let q = (w + 2 * pad - ksize) / stride + 1;
    let d = c * ksize * ksize;
    debug_assert_eq!(rows.len(), n * p * q * d);
    out.resize(n * c * h * w, 0.0);
    out.fill(0.0);
    for ni in 0..n {
        for pi in 0..p {
            for qi in 0..q {
                let row = ((ni * p + pi) * q + qi) * d;
                let mut col = 0;
                for ci in 0..c {
                    for kh in 0..ksize {
                        let hy = (pi * stride + kh) as isize - pad as isize;
                        for kw in 0..ksize {
                            let wx = (qi * stride + kw) as isize - pad as isize;
                            if hy >= 0
                                && (hy as usize) < h
                                && wx >= 0
                                && (wx as usize) < w
                            {
                                out[((ni * c + ci) * h + hy as usize) * w + wx as usize] +=
                                    rows[row + col];
                            }
                            col += 1;
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    fn rand_t(rng: &mut Pcg32, shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor::new(shape, rng.normal_vec(n, 1.0))
    }

    #[test]
    fn naive_known_values() {
        let a = Tensor::new(&[2, 2], vec![1., 2., 3., 4.]);
        let b = Tensor::new(&[2, 2], vec![1., 1., 1., 1.]);
        let c = matmul_naive(&a, &b);
        assert_eq!(c.data(), &[3., 3., 7., 7.]);
    }

    #[test]
    fn blocked_matches_naive() {
        let mut rng = Pcg32::seeded(11);
        for &(m, k, n) in &[(1, 1, 1), (7, 13, 5), (64, 256, 32), (100, 300, 70)] {
            let a = rand_t(&mut rng, &[m, k]);
            let b = rand_t(&mut rng, &[k, n]);
            let want = matmul_naive(&a, &b);
            let got = matmul_blocked(&a, &b);
            assert!(got.allclose(&want, 1e-3, 1e-3), "({m},{k},{n})");
        }
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Pcg32::seeded(12);
        let a = rand_t(&mut rng, &[5, 9]);
        let t = transpose(&transpose(&a));
        assert_eq!(a, t);
        let mut buf = vec![f32::NAN; 1]; // wrong size: must be resized
        transpose_into(a.data(), 5, 9, &mut buf);
        assert_eq!(buf, transpose(&a).data());
    }

    #[test]
    fn relu_clamps() {
        let mut t = Tensor::new(&[4], vec![-1.0, 0.0, 2.0, -0.5]);
        relu_inplace(&mut t);
        assert_eq!(t.data(), &[0.0, 0.0, 2.0, 0.0]);
    }

    #[test]
    fn im2col_identity_kernel() {
        // 1x1 kernel, no pad: rows are just the channel pixels.
        let x = Tensor::from_fn(&[1, 2, 2, 2], |i| i as f32);
        let (rows, p, q) = im2col(&x, 1, 1, 0);
        assert_eq!((p, q), (2, 2));
        assert_eq!(rows.shape(), &[4, 2]);
        // row for (h=0,w=1) = [x[0,0,0,1], x[0,1,0,1]] = [1, 5]
        assert_eq!(rows.at2(1, 0), 1.0);
        assert_eq!(rows.at2(1, 1), 5.0);
    }

    #[test]
    fn im2col_conv_equals_direct() {
        // conv via im2col x weight-matrix == direct convolution
        let mut rng = Pcg32::seeded(13);
        let (n, c, h, w, kk, co) = (2, 3, 6, 6, 3, 4);
        let x = rand_t(&mut rng, &[n, c, h, w]);
        let wt = rand_t(&mut rng, &[co, c * kk * kk]); // (K, CRS)
        let (rows, p, q) = im2col(&x, kk, 1, 1);
        let y = matmul_naive(&rows, &transpose(&wt)); // (NPQ, K)
        // direct conv at a few positions
        for &(ni, ko, pi, qi) in &[(0, 0, 0, 0), (1, 3, 5, 5), (0, 2, 3, 1)] {
            let mut acc = 0.0f32;
            for ci in 0..c {
                for kh in 0..kk {
                    for kw in 0..kk {
                        let hy = pi as isize + kh as isize - 1;
                        let wx = qi as isize + kw as isize - 1;
                        if hy >= 0 && (hy as usize) < h && wx >= 0 && (wx as usize) < w
                        {
                            let xv = x.at4(ni, ci, hy as usize, wx as usize);
                            let wv = wt.at2(ko, (ci * kk + kh) * kk + kw);
                            acc += xv * wv;
                        }
                    }
                }
            }
            let row = (ni * p + pi) * q + qi;
            let got = y.at2(row, ko);
            assert!((got - acc).abs() < 1e-3, "{got} vs {acc}");
        }
    }

    #[test]
    fn into_variants_match_allocating() {
        let mut rng = Pcg32::seeded(14);
        let a = rand_t(&mut rng, &[9, 33]);
        let b = rand_t(&mut rng, &[33, 12]);
        let want = matmul_blocked(&a, &b);
        let mut out = vec![f32::NAN; 9 * 12];
        matmul_blocked_into(a.data(), 9, 33, b.data(), 12, &mut out);
        assert_eq!(out, want.data());

        let x = rand_t(&mut rng, &[2, 3, 5, 5]);
        let (rows, p, q) = im2col(&x, 3, 1, 1);
        let mut buf = vec![f32::NAN; 1]; // wrong size: must be resized
        let (p2, q2) = im2col_slice_into(x.data(), 2, 3, 5, 5, 3, 1, 1, &mut buf);
        assert_eq!((p, q), (p2, q2));
        assert_eq!(buf, rows.data());
    }

    #[test]
    fn col2im_is_adjoint_of_im2col() {
        // <im2col(x), R> == <x, col2im(R)> for random x, R — the defining
        // property of the transpose map the conv backward relies on.
        let mut rng = Pcg32::seeded(15);
        for &(n, c, h, w, kk, stride, pad) in
            &[(2usize, 3usize, 6usize, 6usize, 3usize, 1usize, 1usize), (1, 2, 5, 5, 3, 2, 0), (2, 1, 4, 4, 2, 2, 1)]
        {
            let x = rand_t(&mut rng, &[n, c, h, w]);
            let (rows, p, q) = im2col(&x, kk, stride, pad);
            let r = rand_t(&mut rng, &[n * p * q, c * kk * kk]);
            let mut back = Vec::new();
            col2im_slice_into(r.data(), n, c, h, w, kk, stride, pad, &mut back);
            let lhs: f64 = rows.data().iter().zip(r.data()).map(|(&a, &b)| (a * b) as f64).sum();
            let rhs: f64 = x.data().iter().zip(&back).map(|(&a, &b)| (a * b) as f64).sum();
            assert!(
                (lhs - rhs).abs() < 1e-3 * lhs.abs().max(1.0),
                "({n},{c},{h},{w},k{kk},s{stride},p{pad}): {lhs} vs {rhs}"
            );
        }
    }

    #[test]
    fn col2im_counts_overlaps() {
        // all-ones rows: each input position receives one contribution
        // per sliding window that covers it (3x3, stride 1, pad 1 on 3x3
        // => corner 4, edge 6, center 9)
        let (n, c, h, w) = (1usize, 1usize, 3usize, 3usize);
        let rows = vec![1.0f32; 9 * 9];
        let mut out = Vec::new();
        col2im_slice_into(&rows, n, c, h, w, 3, 1, 1, &mut out);
        assert_eq!(out, vec![4.0, 6.0, 4.0, 6.0, 9.0, 6.0, 4.0, 6.0, 4.0]);
    }

    #[test]
    fn im2col_stride2() {
        let x = Tensor::from_fn(&[1, 1, 4, 4], |i| i as f32);
        let (rows, p, q) = im2col(&x, 2, 2, 0);
        assert_eq!((p, q), (2, 2));
        assert_eq!(rows.shape(), &[4, 4]);
        // window at (0,0): pixels 0,1,4,5
        assert_eq!(rows.data()[0..4], [0., 1., 4., 5.]);
    }
}
