//! Native-engine parity: the rust-native forward (real column skipping)
//! must agree with the HLO forward (Pallas mask-multiply) on the same
//! checkpointed weights.  Small float divergence near the top-k
//! threshold can flip individual mask bits, so parity is asserted on
//! predictions and logit closeness, not bit-exactness.

use dsg::coordinator::Trainer;
use dsg::datasets;
use dsg::native::{Mode, NativeModel};
use dsg::runtime::{Meta, Runtime};
use dsg::Tensor;

/// A live PJRT runtime, or `None` (skip) when the `xla` feature or the
/// HLO artifacts are absent — parity needs both sides to exist.
fn runtime() -> Option<Runtime> {
    if !cfg!(feature = "xla") {
        eprintln!("skipping: dsg built without the `xla` feature");
        return None;
    }
    if !dsg::artifacts_dir().join("index.json").exists() {
        eprintln!("skipping: artifacts not built — run `make artifacts` first");
        return None;
    }
    Some(Runtime::cpu().unwrap())
}

fn trained(rt: &Runtime, variant: &str, steps: usize) -> Trainer {
    let dir = dsg::artifacts_dir();
    let meta = Meta::load(&dir, variant).unwrap();
    let mut cfg = dsg::config::RunConfig::preset_for_model(variant);
    cfg.steps = steps;
    cfg.eval_every = 0;
    let data = datasets::fashion_like(768, 21);
    let (train, test) = data.split(0.25);
    let mut t = Trainer::new(rt, meta, 21).unwrap();
    t.train(&cfg, &train, &test).unwrap();
    t
}

fn batch_for(t: &Trainer) -> (Vec<f32>, Tensor) {
    let data = datasets::fashion_like(t.meta.batch, 77);
    let (xs, _) = datasets::BatchIter::new(&data, t.meta.batch, 1).next_batch();
    let mut shape = vec![t.meta.batch];
    shape.extend_from_slice(&t.meta.input_shape);
    let xt = Tensor::new(&shape, xs.clone());
    (xs, xt)
}

#[test]
fn mlp_native_matches_hlo_dense() {
    // gamma = 0: no masks in play, logits must agree to float tolerance.
    let Some(rt) = runtime() else { return };
    let t = trained(&rt, "mlp", 40);
    let native = NativeModel::new(&t.meta, &t.state).unwrap();
    let (xs, xt) = batch_for(&t);
    let hlo = t.forward(&xs, 0.0).unwrap();
    let nat = native.forward(&xt, 0.0, Mode::Dsg).unwrap();
    let maxdiff = hlo
        .iter()
        .zip(nat.logits.data())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(maxdiff < 2e-2, "dense-path logit divergence {maxdiff}");
}

#[test]
fn mlp_native_matches_hlo_sparse() {
    let Some(rt) = runtime() else { return };
    let t = trained(&rt, "mlp", 40);
    let native = NativeModel::new(&t.meta, &t.state).unwrap();
    let (xs, xt) = batch_for(&t);
    let gamma = 0.7;
    let hlo = t.forward(&xs, gamma).unwrap();
    let nat = native.forward(&xt, gamma, Mode::Dsg).unwrap();
    // predictions agree on nearly every sample
    let c = t.meta.classes;
    let argmax = |row: &[f32]| {
        row.iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(j, _)| j)
            .unwrap()
    };
    let mut agree = 0;
    for i in 0..t.meta.batch {
        let a = argmax(&hlo[i * c..(i + 1) * c]);
        let b = argmax(&nat.logits.data()[i * c..(i + 1) * c]);
        if a == b {
            agree += 1;
        }
    }
    assert!(
        agree as f64 / t.meta.batch as f64 > 0.95,
        "only {agree}/{} predictions agree at gamma {gamma}",
        t.meta.batch
    );
    // densities match the gamma target
    for s in &nat.stats {
        assert!((s.density - (1.0 - gamma) as f64).abs() < 0.12, "{s:?}");
    }
}

#[test]
fn lenet_native_conv_path_matches() {
    let Some(rt) = runtime() else { return };
    let t = trained(&rt, "lenet", 40);
    let native = NativeModel::new(&t.meta, &t.state).unwrap();
    let (xs, xt) = batch_for(&t);
    let hlo = t.forward(&xs, 0.0).unwrap();
    let nat = native.forward(&xt, 0.0, Mode::Dsg).unwrap();
    let maxdiff = hlo
        .iter()
        .zip(nat.logits.data())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(maxdiff < 5e-2, "conv dense-path logit divergence {maxdiff}");
}

#[test]
fn lenet_native_sparse_agrees_on_predictions() {
    let Some(rt) = runtime() else { return };
    let t = trained(&rt, "lenet", 40);
    let native = NativeModel::new(&t.meta, &t.state).unwrap();
    let (xs, xt) = batch_for(&t);
    let gamma = 0.6;
    let hlo = t.forward(&xs, gamma).unwrap();
    let preds = native.predict(&xt, gamma, Mode::Dsg).unwrap();
    let c = t.meta.classes;
    let mut agree = 0;
    for (i, &p) in preds.iter().enumerate() {
        let row = &hlo[i * c..(i + 1) * c];
        let a = row
            .iter()
            .enumerate()
            .max_by(|x, y| x.1.total_cmp(y.1))
            .map(|(j, _)| j)
            .unwrap();
        if a == p {
            agree += 1;
        }
    }
    assert!(
        agree as f64 / preds.len() as f64 > 0.9,
        "only {agree}/{} conv predictions agree",
        preds.len()
    );
}

#[test]
fn native_dsg_is_faster_than_native_dense_at_high_sparsity() {
    // The whole point: on the native engine the mask removes real work.
    let Some(rt) = runtime() else { return };
    let t = trained(&rt, "lenet", 10);
    let native = NativeModel::new(&t.meta, &t.state).unwrap();
    let (_, xt) = batch_for(&t);
    // warmup
    native.forward(&xt, 0.9, Mode::Dsg).unwrap();
    let t0 = std::time::Instant::now();
    let sparse = native.forward(&xt, 0.9, Mode::Dsg).unwrap();
    let t_sparse: f64 = sparse.stats.iter().map(|s| s.secs - s.drs_secs).sum();
    let _ = t0.elapsed();
    let dense = native.forward(&xt, 0.0, Mode::Dense).unwrap();
    let t_dense: f64 = dense.stats.iter().map(|s| s.secs).sum();
    assert!(
        t_sparse < t_dense,
        "post-search sparse exec {t_sparse:.4}s not faster than dense {t_dense:.4}s"
    );
}

#[test]
fn native_rejects_meta_without_topology() {
    let dir = dsg::artifacts_dir();
    if !dir.join("index.json").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let mut meta = Meta::load(&dir, "mlp").unwrap();
    meta.units.clear();
    let st = dsg::coordinator::ModelState::init(&meta, 1);
    assert!(NativeModel::new(&meta, &st).is_err());
}
