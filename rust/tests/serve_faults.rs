//! Fault-hardened serving integration: injected faults at every serving
//! site must degrade gracefully — a retried batch, a dropped
//! connection, a backed-off accept loop — and NEVER change served bits
//! or take the server down.  Companion to `serve_wire.rs` (the no-fault
//! transparency suite).
//!
//! Every test that arms a process-global fault plan serializes on
//! `faults::test_guard()` and reads counters as deltas, so the suite is
//! order-independent.

use dsg::metrics::recovery;
use dsg::serve::server::{
    drive_load, drive_load_with, ClientOptions, Endpoint, ServerTuning, WireServer,
};
use dsg::serve::wire::{read_frame, write_frame, Message};
use dsg::serve::{ShardedConfig, ShardedServer, SynthModel};
use dsg::util::faults::{self, FaultKind, FaultPlan};
use std::time::{Duration, Instant};

const DIMS: &[usize] = &[64, 96, 80];
const CLASSES: usize = 10;
const BATCH: usize = 8;
const GAMMA: f32 = 0.7;

fn images(n: usize) -> Vec<Vec<f32>> {
    let m = SynthModel::new(1, DIMS, CLASSES, GAMMA);
    (0..n).map(|i| m.synth_image(500 + i as u64)).collect()
}

fn wire_cfg(shards: usize, workers: usize) -> ShardedConfig {
    ShardedConfig::new(shards, workers, BATCH, DIMS[0], CLASSES)
        .with_max_wait(Duration::from_secs(60))
}

fn model_forward(intra: usize) -> impl Fn(&[f32]) -> anyhow::Result<Vec<f32>> + Send + Sync {
    let model = SynthModel::new(1, DIMS, CLASSES, GAMMA).with_intra_threads(intra);
    move |xs: &[f32]| model.forward(xs, BATCH)
}

#[test]
fn accept_fault_backs_off_and_still_serves() {
    let _g = faults::test_guard();
    let before = recovery().snapshot();
    // the first accept poll fails (as EMFILE/EINTR would); the listener
    // must absorb it and serve the whole load afterwards
    faults::install(&FaultPlan::one("accept", FaultKind::Io, 1, false));
    let server =
        WireServer::bind(&Endpoint::parse("127.0.0.1:0"), wire_cfg(2, 2), model_forward(1))
            .unwrap();
    let addr = server.local_endpoint().clone();
    let handle = std::thread::spawn(move || server.run().unwrap());
    let imgs = images(16);
    let run = drive_load(&addr, &imgs, true).unwrap();
    let report = handle.join().unwrap();
    faults::clear();
    assert_eq!(run.served(), 16, "an accept fault must not lose requests");
    assert_eq!(report.served, 16);
    let d = recovery().snapshot().since(&before);
    assert!(d.accept_backoffs >= 1, "backoff not counted: {d:?}");
    assert!(d.faults_injected >= 1);
}

#[test]
fn worker_batch_fault_is_retried_bit_exact() {
    // ground truth FIRST, before any plan is armed
    let imgs = images(16);
    let baseline = {
        let _g = faults::test_guard();
        ShardedServer::serve_all(wire_cfg(1, 1), model_forward(1), imgs.clone()).unwrap()
    };

    let _g = faults::test_guard();
    let before = recovery().snapshot();
    // exactly one batch execution fails; batch_retries (default 1)
    // must re-run the SAME assembled batch — so every prediction is
    // still the deterministic one
    faults::install(&FaultPlan::one("serve.worker_batch", FaultKind::Io, 1, false));
    let server =
        WireServer::bind(&Endpoint::parse("127.0.0.1:0"), wire_cfg(1, 1), model_forward(1))
            .unwrap();
    let addr = server.local_endpoint().clone();
    let handle = std::thread::spawn(move || server.run().unwrap());
    let run = drive_load(&addr, &imgs, true).unwrap();
    let report = handle.join().unwrap();
    faults::clear();

    assert_eq!(run.served(), 16);
    assert_eq!(
        run.predictions(),
        baseline.predictions(),
        "a retried batch changed served bits"
    );
    assert_eq!(report.failed, 0, "the retry must absorb the fault");
    assert!(report.retries >= 1, "retry not accounted");
    let d = recovery().snapshot().since(&before);
    assert!(d.batch_retries >= 1, "{d:?}");
}

#[test]
fn wire_read_fault_kills_connection_not_server() {
    let _g = faults::test_guard();
    let before = recovery().snapshot();
    faults::install(&FaultPlan::one("wire.read", FaultKind::Io, 1, false));
    let server =
        WireServer::bind(&Endpoint::parse("127.0.0.1:0"), wire_cfg(1, 1), model_forward(1))
            .unwrap();
    let addr = server.local_endpoint().clone();
    let handle = std::thread::spawn(move || server.run().unwrap());

    // connection 1 hits the injected read fault: the server must drop
    // it (the client sees a failed handshake), not die
    let err = drive_load(&addr, &images(4), false);
    assert!(err.is_err(), "connection with injected read fault must fail");

    // connection 2 serves normally on the same server
    let run = drive_load(&addr, &images(10), true).unwrap();
    let report = handle.join().unwrap();
    faults::clear();
    assert_eq!(run.served(), 10);
    assert_eq!(report.served, 10);
    let d = recovery().snapshot().since(&before);
    assert!(d.disconnects_error >= 1, "{d:?}");
    assert_eq!(d.conns_opened, 2); // the faulted conn + the serving one
}

#[test]
fn slow_client_write_queue_overflow_disconnects() {
    let _g = faults::test_guard();
    let before = recovery().snapshot();
    // break the writer (persistent wire.write fault) so the bounded
    // queue can't drain, and read NOTHING from the client side: reply
    // hooks must hit the Full queue, flag the connection slow, and the
    // reader must disconnect it — without ever blocking a worker
    faults::install(&FaultPlan::one("wire.write", FaultKind::Io, 1, true));
    let tuning = ServerTuning {
        idle_timeout: Duration::from_millis(200),
        write_timeout: Duration::from_secs(5),
        write_queue: 4,
        accept_backoff_max: Duration::from_millis(100),
    };
    let server = WireServer::bind_tuned(
        &Endpoint::parse("127.0.0.1:0"),
        wire_cfg(1, 1),
        tuning,
        model_forward(1),
    )
    .unwrap();
    let addr = server.local_endpoint().clone();
    let Endpoint::Tcp(tcp_addr) = addr.clone() else { panic!("expected tcp") };
    let handle = std::thread::spawn(move || server.run().unwrap());

    let imgs = images(30);
    let mut w = std::net::TcpStream::connect(&tcp_addr).unwrap();
    for (i, img) in imgs.iter().enumerate() {
        write_frame(&mut w, &Message::Request { id: i as u64, image: img.clone() }).unwrap();
    }
    write_frame(&mut w, &Message::Flush).unwrap();
    // never read; wait for the server to give up on us
    let t0 = Instant::now();
    loop {
        let d = recovery().snapshot().since(&before);
        if d.disconnects_slow >= 1 {
            break;
        }
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "slow client never disconnected: {d:?}"
        );
        std::thread::sleep(Duration::from_millis(10));
    }
    drop(w);

    // the server survived the slow client: disarm the write fault and
    // serve a fresh connection end to end
    faults::clear();
    let run = drive_load(&addr, &images(8), true).unwrap();
    let _report = handle.join().unwrap();
    assert_eq!(run.served(), 8);
}

#[test]
fn shutdown_is_acked_after_in_flight_replies_are_honored() {
    let _g = faults::test_guard();
    let before = recovery().snapshot();
    let server =
        WireServer::bind(&Endpoint::parse("127.0.0.1:0"), wire_cfg(2, 2), model_forward(1))
            .unwrap();
    let Endpoint::Tcp(tcp_addr) = server.local_endpoint().clone() else { panic!("expected tcp") };
    let handle = std::thread::spawn(move || server.run().unwrap());

    // one connection: requests, Flush, Shutdown back to back — the
    // graceful drain must still deliver EVERY response plus the ack
    let imgs = images(10);
    let s = std::net::TcpStream::connect(&tcp_addr).unwrap();
    let mut w = s.try_clone().unwrap();
    let mut r = std::io::BufReader::new(s);
    for (i, img) in imgs.iter().enumerate() {
        write_frame(&mut w, &Message::Request { id: i as u64, image: img.clone() }).unwrap();
    }
    write_frame(&mut w, &Message::Flush).unwrap();
    write_frame(&mut w, &Message::Shutdown).unwrap();

    let mut responses = 0usize;
    let mut acked = false;
    while responses < imgs.len() || !acked {
        match read_frame(&mut r).unwrap() {
            Some(Message::Response { .. }) => responses += 1,
            Some(Message::ShutdownAck) => acked = true,
            Some(other) => panic!("unexpected frame during drain: {other:?}"),
            None => panic!("socket closed with {responses} responses, ack {acked}"),
        }
    }
    let report = handle.join().unwrap();
    assert_eq!(report.served, 10);
    assert_eq!(report.failed, 0);
    let d = recovery().snapshot().since(&before);
    assert!(d.drains >= 1, "{d:?}");
}

#[test]
fn client_retries_turn_overload_rejects_into_throughput() {
    let _g = faults::test_guard();
    let before = recovery().snapshot();
    // tiny queue + slow forward: the burst overloads admission, and a
    // retrying client must eventually get EVERYTHING served
    let cfg = ShardedConfig::new(1, 1, BATCH, DIMS[0], CLASSES)
        .with_queue_cap(1)
        .with_max_wait(Duration::from_millis(1));
    let model = SynthModel::new(1, DIMS, CLASSES, GAMMA);
    let server = WireServer::bind(&Endpoint::parse("127.0.0.1:0"), cfg, move |xs: &[f32]| {
        std::thread::sleep(Duration::from_millis(5));
        model.forward(xs, BATCH)
    })
    .unwrap();
    let addr = server.local_endpoint().clone();
    let handle = std::thread::spawn(move || server.run().unwrap());
    let imgs = images(120);
    let opts = ClientOptions { shutdown_after: true, retries: 10, ..Default::default() };
    let run = drive_load_with(&addr, &imgs, &opts).unwrap();
    let report = handle.join().unwrap();

    assert!(run.retries > 0, "a 120-burst past a 1-block cap must retry");
    assert_eq!(run.served(), 120, "retries must converge to full service");
    assert_eq!(run.rejected(), 0, "no terminal rejects after retry rounds");
    assert!(report.rejected > 0, "the server did shed load along the way");
    assert_eq!(report.served, 120);
    let d = recovery().snapshot().since(&before);
    assert!(d.client_retries >= run.retries as u64, "{d:?}");
}
