//! Pool + RowMask hot-path integration: the pool-backed engines must be
//! bit-exact vs the single-threaded reference engines and across thread
//! budgets {1, 2, 3, 8}; RowMask must agree with the dense-mask engines
//! on every selection shape (empty rows, keep-all, mixed); and the
//! persistent pool + pooled workspaces must survive heavy reuse —
//! repeated forwards, many dispatches, concurrent dispatchers.

use dsg::drs::projection::{ternary_r, TernaryIndex};
use dsg::drs::topk::{self, RowMask};
use dsg::native::ForwardWorkspace;
use dsg::serve::SynthModel;
use dsg::sparse::{self, parallel};
use dsg::tensor::{ops, Tensor};
use dsg::util::Pcg32;

const BUDGETS: [usize; 4] = [1, 2, 3, 8];

fn randn(rng: &mut Pcg32, shape: &[usize]) -> Tensor {
    let n: usize = shape.iter().product();
    Tensor::new(shape, rng.normal_vec(n, 1.0))
}

#[test]
fn pool_engines_bit_exact_vs_reference_across_budgets() {
    let mut rng = Pcg32::seeded(901);
    let x = randn(&mut rng, &[33, 96]);
    let w = randn(&mut rng, &[96, 41]);
    let wt = ops::transpose(&w);
    let virt = randn(&mut rng, &[33, 41]);
    let rm = topk::select_rowmask(&virt, 0.7);
    let dense = rm.to_dense();
    let r = ternary_r(&mut rng, 16, 96, 3);
    let ridx = TernaryIndex::from_dense(&r);

    // single-threaded references
    let vmm_ref = sparse::dsg_vmm(&x, &wt, &dense);
    let rowmask_ref = sparse::dsg_vmm_rowmask(&x, &wt, &rm);
    let proj_ref = dsg::drs::project_rows(&x, &r);
    assert_eq!(vmm_ref, rowmask_ref, "RowMask reference != dense reference");

    for t in BUDGETS {
        assert_eq!(
            vmm_ref,
            parallel::dsg_vmm_parallel_with(&x, &wt, &dense, t),
            "dense vmm @ {t}"
        );
        assert_eq!(
            rowmask_ref,
            parallel::dsg_vmm_rowmask_parallel_with(&x, &wt, &rm, t),
            "rowmask vmm @ {t}"
        );
        assert_eq!(proj_ref, parallel::project_rows_parallel_with(&x, &ridx, t), "proj @ {t}");
    }
    // the pool matmul kernel is budget-invariant (it intentionally
    // differs from the serial blocked reference kernel)
    let mm1 = parallel::matmul_parallel_with(&x, &w, 1);
    for t in BUDGETS {
        assert_eq!(mm1, parallel::matmul_parallel_with(&x, &w, t), "matmul @ {t}");
    }
    assert!(mm1.allclose(&ops::matmul_blocked(&x, &w), 1e-3, 1e-3));
}

#[test]
fn empty_mask_rows_produce_zero_rows() {
    let mut rng = Pcg32::seeded(902);
    let x = randn(&mut rng, &[5, 32]);
    let w = randn(&mut rng, &[32, 9]);
    let wt = ops::transpose(&w);
    // rows 1 and 3 select nothing
    let dense = Tensor::from_fn(&[5, 9], |i| {
        let row = i / 9;
        if row == 1 || row == 3 {
            0.0
        } else if i % 2 == 0 {
            1.0
        } else {
            0.0
        }
    });
    let rm = RowMask::from_dense(&dense);
    assert!(rm.row(1).is_empty() && rm.row(3).is_empty());
    for t in BUDGETS {
        let y = parallel::dsg_vmm_rowmask_parallel_with(&x, &wt, &rm, t);
        assert_eq!(y, sparse::dsg_vmm(&x, &wt, &dense), "budget {t}");
        for row in [1usize, 3] {
            assert!(
                y.data()[row * 9..(row + 1) * 9].iter().all(|&v| v == 0.0),
                "empty-mask row {row} not zero @ budget {t}"
            );
        }
    }
}

#[test]
fn gamma_zero_keep_all_fast_path_is_exact() {
    let mut rng = Pcg32::seeded(903);
    let x = randn(&mut rng, &[7, 64]);
    let w = randn(&mut rng, &[64, 15]);
    let wt = ops::transpose(&w);
    let virt = randn(&mut rng, &[7, 15]);
    let rm = topk::select_rowmask(&virt, 0.0);
    assert!(rm.is_full(), "gamma=0 must select everything");
    // the full-mask fast path equals the dense VMM bit-for-bit, at
    // every budget, and matches a dense GEMM numerically
    let want = sparse::vmm(&x, &wt);
    assert_eq!(want, sparse::dsg_vmm_rowmask(&x, &wt, &rm));
    for t in BUDGETS {
        assert_eq!(want, parallel::dsg_vmm_rowmask_parallel_with(&x, &wt, &rm, t), "budget {t}");
    }
    assert!(want.allclose(&ops::matmul_naive(&x, &w), 1e-3, 1e-3));
}

#[test]
fn compound_engine_bit_exact_vs_references_across_budgets() {
    // the compound (input + output sparsity) engine against BOTH
    // references — dense-mask scan and RowMask jump — on a sparse input
    // with signed zeros, for every budget and every layer hint
    let mut rng = Pcg32::seeded(905);
    let mut xv = Pcg32::seeded(906).normal_vec(33 * 96, 1.0);
    for (i, v) in xv.iter_mut().enumerate() {
        if i % 4 == 0 {
            *v = 0.0;
        } else if i % 9 == 0 {
            *v = -0.0;
        }
    }
    let x = Tensor::new(&[33, 96], xv);
    let w = randn(&mut rng, &[96, 41]);
    let wt = ops::transpose(&w);
    for gamma in [0.0f32, 0.7] {
        let virt = randn(&mut rng, &[33, 41]);
        let rm = topk::select_rowmask(&virt, gamma);
        let want = sparse::dsg_vmm(&x, &wt, &rm.to_dense());
        assert_eq!(want, sparse::dsg_vmm_rowmask(&x, &wt, &rm));
        let (serial, serial_ops) = sparse::dsg_vmm_compound(&x, &wt, &rm);
        assert_eq!(want, serial, "serial compound, gamma {gamma}");
        assert!(serial_ops <= 96u64 * rm.selected() as u64);
        for t in BUDGETS {
            for hint in [0.0f32, 0.5, 1.0] {
                let (got, _) = parallel::dsg_vmm_compound_parallel_with(&x, &wt, &rm, hint, t);
                assert_eq!(want, got, "gamma {gamma} hint {hint} budget {t}");
            }
        }
    }
}

#[test]
fn structured_masks_bit_exact_across_budgets_and_vs_csr() {
    // the structured (FixedK) twin of the budget-invariance claims: the
    // same selection expressed packed and as CSR must agree with the
    // dense-mask reference and with itself at every budget, for the
    // plain and the blocked fan-in, through forward AND compound
    let mut rng = Pcg32::seeded(910);
    let mut xv = rng.normal_vec(33 * 96, 1.0);
    for (i, v) in xv.iter_mut().enumerate() {
        if i % 4 == 0 {
            *v = 0.0;
        } else if i % 9 == 0 {
            *v = -0.0;
        }
    }
    let x = Tensor::new(&[33, 96], xv);
    let w = randn(&mut rng, &[96, 41]);
    let wt = ops::transpose(&w);
    let virt = randn(&mut rng, &[33, 41]);
    for blocked in [false, true] {
        let rm = topk::select_structured(&virt, 0.7, blocked);
        let k = rm.fixed_k().expect("structured selection must be packed");
        assert_eq!(k, topk::structured_k(41, 0.7, blocked));
        if blocked {
            assert_eq!(k % 4, 0, "blocked k not 4-aligned");
        }
        for i in 0..33 {
            assert_eq!(rm.row(i).len(), k, "row {i} fan-in");
            assert!(rm.row(i).windows(2).all(|p| p[0] < p[1]), "row {i} not ascending");
        }
        let csr = rm.to_csr();
        assert!(csr.fixed_k().is_none());
        let want = sparse::dsg_vmm(&x, &wt, &rm.to_dense());
        assert_eq!(want, sparse::dsg_vmm_rowmask(&x, &wt, &rm), "serial CSR kernel on packed");
        for t in BUDGETS {
            assert_eq!(
                want,
                parallel::dsg_vmm_rowmask_parallel_with(&x, &wt, &rm, t),
                "packed blocked {blocked} @ {t}"
            );
            assert_eq!(
                want,
                parallel::dsg_vmm_rowmask_parallel_with(&x, &wt, &csr, t),
                "csr blocked {blocked} @ {t}"
            );
            for hint in [0.0f32, 0.5, 1.0] {
                let (got, _) = parallel::dsg_vmm_compound_parallel_with(&x, &wt, &rm, hint, t);
                assert_eq!(want, got, "compound blocked {blocked} hint {hint} @ {t}");
            }
        }
    }
}

#[test]
fn structured_k_equals_width_is_keep_all_and_k_zero_is_empty() {
    let mut rng = Pcg32::seeded(911);
    let x = randn(&mut rng, &[9, 40]);
    let w = randn(&mut rng, &[40, 24]);
    let wt = ops::transpose(&w);
    let virt = randn(&mut rng, &[9, 24]);
    // gamma 0 => k = width: canonicalizes to the SAME implicit keep-all
    // mask as the unstructured path, so dense / keep-all / structured
    // all agree to the bit
    let st = topk::select_structured(&virt, 0.0, false);
    assert!(st.is_full());
    assert_eq!(st, topk::select_rowmask(&virt, 0.0));
    let want = sparse::vmm(&x, &wt);
    for t in BUDGETS {
        assert_eq!(want, parallel::dsg_vmm_rowmask_parallel_with(&x, &wt, &st, t), "@ {t}");
    }
    // k = 0: every row empty, every output row zero, zero realized ops
    let mut empty = RowMask::new();
    empty.fill_topk(virt.data(), 9, 24, 0, &mut Vec::new());
    assert_eq!(empty.fixed_k(), Some(0));
    assert_eq!(empty.nbytes(), 0);
    for t in BUDGETS {
        let y = parallel::dsg_vmm_rowmask_parallel_with(&x, &wt, &empty, t);
        assert!(y.data().iter().all(|&v| v == 0.0), "@ {t}");
        let (yc, realized) = parallel::dsg_vmm_compound_parallel_with(&x, &wt, &empty, 0.3, t);
        assert_eq!(y, yc);
        assert_eq!(realized, 0);
    }
}

#[test]
fn packed_nbytes_is_rows_times_k() {
    let mut rng = Pcg32::seeded(912);
    let virt = randn(&mut rng, &[12, 50]);
    let rm = topk::select_structured(&virt, 0.6, false);
    let k = rm.fixed_k().unwrap();
    assert_eq!(rm.nbytes(), 4 * 12 * k, "FixedK charges indices only");
    let csr = rm.to_csr();
    assert!(
        csr.nbytes() > rm.nbytes(),
        "CSR of the same selection must carry the offsets array on top"
    );
    assert_eq!(csr.selected(), rm.selected());
}

#[test]
fn pool_survives_repeated_forwards_and_stays_deterministic() {
    // many forwards through the same model = many pool dispatches; the
    // persistent pool and the workspace pool must give identical bits
    // every time
    let m = SynthModel::new(21, &[48, 64, 56], 10, 0.8).with_intra_threads(3);
    let xs: Vec<f32> = Pcg32::seeded(500).normal_vec(6 * 48, 1.0);
    let first = m.forward(&xs, 6).unwrap();
    for rep in 0..20 {
        assert_eq!(first, m.forward(&xs, 6).unwrap(), "rep {rep} diverged");
    }
}

#[test]
fn workspace_reuse_across_shapes_and_requests() {
    // one explicit workspace reused across DIFFERENT models and batch
    // shapes must still match the pooled path bit-for-bit
    let small = SynthModel::new(31, &[32, 40], 6, 0.5).with_intra_threads(2);
    let big = SynthModel::new(32, &[80, 96, 64], 9, 0.75).with_intra_threads(2);
    let mut ws = ForwardWorkspace::new();
    for i in 0..3u64 {
        let xs: Vec<f32> = Pcg32::seeded(600 + i).normal_vec(4 * 32, 1.0);
        let xb: Vec<f32> = Pcg32::seeded(700 + i).normal_vec(2 * 80, 1.0);
        assert_eq!(
            small.forward(&xs, 4).unwrap(),
            small.forward_with_workspace(&xs, 4, &mut ws).unwrap(),
            "small model, round {i}"
        );
        assert_eq!(
            big.forward(&xb, 2).unwrap(),
            big.forward_with_workspace(&xb, 2, &mut ws).unwrap(),
            "big model, round {i}"
        );
    }
}

#[test]
fn concurrent_dispatchers_stay_bit_exact() {
    // serve-like contention: several OS threads hammer the shared global
    // pool at different budgets; every result must equal the serial one
    let mut rng = Pcg32::seeded(904);
    let x = randn(&mut rng, &[29, 80]);
    let w = randn(&mut rng, &[80, 33]);
    let want = parallel::matmul_parallel_with(&x, &w, 1);
    std::thread::scope(|scope| {
        for t in [2usize, 3, 4, 8] {
            let (x, w, want) = (&x, &w, &want);
            scope.spawn(move || {
                for _ in 0..15 {
                    assert_eq!(*want, parallel::matmul_parallel_with(x, w, t));
                }
            });
        }
    });
}
