//! Sharded-serving integration: the production front-end over the
//! synthetic DSG model must produce bit-identical predictions for ANY
//! shard count and ANY worker count — and agree with both the
//! single-queue `ConcurrentServer` and the single-threaded `Batcher`
//! pump — because block composition is fixed by arrival order, work
//! stealing moves whole blocks, and density shaping only reorders
//! execution.

use dsg::serve::{
    Batcher, ConcurrentServer, Queue, RejectReason, ServerConfig, ShardedConfig, ShardedServer,
    SubmitError, SynthModel,
};
use std::sync::Arc;
use std::time::Duration;

const DIMS: &[usize] = &[64, 96, 80];
const CLASSES: usize = 10;
const BATCH: usize = 8;
const GAMMA: f32 = 0.7;

fn images(n: usize) -> Vec<Vec<f32>> {
    let m = SynthModel::new(1, DIMS, CLASSES, GAMMA);
    (0..n).map(|i| m.synth_image(500 + i as u64)).collect()
}

fn run_sharded(shards: usize, workers: usize, intra: usize, imgs: &[Vec<f32>]) -> Vec<usize> {
    let model = Arc::new(SynthModel::new(1, DIMS, CLASSES, GAMMA).with_intra_threads(intra));
    let cfg = ShardedConfig::new(shards, workers, BATCH, DIMS[0], CLASSES)
        .with_max_wait(Duration::from_millis(5));
    let report =
        ShardedServer::serve_all(cfg, move |xs: &[f32]| model.forward(xs, BATCH), imgs.to_vec())
            .unwrap();
    assert_eq!(report.served, imgs.len());
    assert_eq!(report.failed, 0);
    report.predictions()
}

/// The acceptance-criteria matrix: shard counts {1,2,4} x worker counts
/// {1,2,8} all agree bit-for-bit with the 1x1 run.
#[test]
fn predictions_identical_across_shard_and_worker_counts() {
    let imgs = images(50);
    let base = run_sharded(1, 1, 1, &imgs);
    for shards in [1usize, 2, 4] {
        for workers in [1usize, 2, 8] {
            let got = run_sharded(shards, workers, 1, &imgs);
            assert_eq!(base, got, "{shards} shards x {workers} workers diverged from 1x1");
        }
    }
    // intra-op threading composes with sharding without changing bits
    assert_eq!(base, run_sharded(4, 2, 3, &imgs));
}

#[test]
fn sharded_matches_concurrent_and_baseline_pump() {
    let imgs = images(37);
    let sharded = run_sharded(4, 3, 2, &imgs);

    let model = Arc::new(SynthModel::new(1, DIMS, CLASSES, GAMMA).with_intra_threads(2));
    let m = model.clone();
    let cfg = ServerConfig::new(4, BATCH, DIMS[0], CLASSES).with_max_wait(Duration::from_millis(5));
    let conc = ConcurrentServer::serve_all(cfg, move |xs: &[f32]| m.forward(xs, BATCH), imgs.clone())
        .unwrap();
    assert_eq!(sharded, conc.predictions(), "sharded vs single-queue diverged");

    let baseline_model = SynthModel::new(1, DIMS, CLASSES, GAMMA);
    let mut q = Queue::new();
    for img in &imgs {
        q.push(img.clone());
    }
    let mut b = Batcher::new(BATCH, DIMS[0], CLASSES);
    let baseline = b.pump(&mut q, |xs| baseline_model.forward(xs, BATCH)).unwrap();
    let baseline_preds: Vec<usize> = baseline.iter().map(|r| r.pred).collect();
    assert_eq!(sharded, baseline_preds, "sharded vs single-threaded pump diverged");
}

#[test]
fn density_shaping_is_bit_neutral_on_real_loads() {
    let imgs = images(43);
    let on = {
        let model = Arc::new(SynthModel::new(1, DIMS, CLASSES, GAMMA).with_intra_threads(1));
        let cfg = ShardedConfig::new(2, 4, BATCH, DIMS[0], CLASSES).with_density_shaping(true);
        ShardedServer::serve_all(cfg, move |xs: &[f32]| model.forward(xs, BATCH), imgs.clone())
            .unwrap()
    };
    let off = {
        let model = Arc::new(SynthModel::new(1, DIMS, CLASSES, GAMMA).with_intra_threads(1));
        let cfg = ShardedConfig::new(2, 4, BATCH, DIMS[0], CLASSES).with_density_shaping(false);
        ShardedServer::serve_all(cfg, move |xs: &[f32]| model.forward(xs, BATCH), imgs.clone())
            .unwrap()
    };
    assert_eq!(on.predictions(), off.predictions(), "shaping moved bits, not just time");
    assert_eq!(on.batches, off.batches);
    assert_eq!(on.padded_slots, off.padded_slots);
}

#[test]
fn work_stealing_covers_workerless_shards() {
    // 4 shards, 1 worker: blocks land round-robin on all shards but
    // only shard 0 has a home worker — the rest MUST be stolen, and the
    // answers must still be the 1x1 answers.
    let imgs = images(64); // 8 blocks -> 2 per shard
    let base = run_sharded(1, 1, 1, &imgs);
    let model = Arc::new(SynthModel::new(1, DIMS, CLASSES, GAMMA).with_intra_threads(1));
    let cfg = ShardedConfig::new(4, 1, BATCH, DIMS[0], CLASSES);
    let report =
        ShardedServer::serve_all(cfg, move |xs: &[f32]| model.forward(xs, BATCH), imgs.clone())
            .unwrap();
    assert_eq!(report.predictions(), base);
    assert_eq!(report.stolen, 6, "the 6 blocks on shards 1..3 must be stolen");
    let per_shard_stolen: u64 = report.per_shard.iter().map(|s| s.stolen).sum();
    assert_eq!(per_shard_stolen, 6);
    assert_eq!(report.per_shard[0].stolen, 0, "home-shard blocks must not count as stolen");
}

#[test]
fn overload_burst_rejects_explicitly_and_conserves() {
    let model = Arc::new(SynthModel::new(1, DIMS, CLASSES, GAMMA).with_intra_threads(1));
    let m = model.clone();
    let cfg = ShardedConfig::new(2, 1, BATCH, DIMS[0], CLASSES)
        .with_queue_cap(1)
        .with_max_wait(Duration::from_millis(1));
    let srv = ShardedServer::start(cfg, move |xs: &[f32]| {
        std::thread::sleep(Duration::from_millis(10));
        m.forward(xs, BATCH)
    });
    let mut admitted = 0usize;
    let mut rejected = 0usize;
    for img in images(120) {
        match srv.submit(img) {
            Ok(_) => admitted += 1,
            Err(SubmitError::Rejected(r)) => {
                assert_eq!(r.reason, RejectReason::Overloaded);
                rejected += 1;
            }
            Err(e) => panic!("unexpected submit error: {e}"),
        }
    }
    assert!(rejected > 0, "a 120-request burst past a 1-block cap must reject");
    let report = srv.join();
    assert_eq!(report.served, admitted);
    assert_eq!(report.rejected as usize, rejected);
    assert_eq!(report.served + report.rejected as usize, 120);
    assert_eq!(report.failed, 0);
}

#[test]
fn sharded_panic_survival_fails_one_block_only() {
    // Poison the batch holding request 12 (block [8..16)); every other
    // block must serve, the failed block must report per-request
    // failures, and join must not hang — across shard/worker combos.
    let imgs = images(40);
    let poison = imgs[12].clone();
    for (shards, workers) in [(1usize, 1usize), (2, 4)] {
        let model = Arc::new(SynthModel::new(1, DIMS, CLASSES, GAMMA).with_intra_threads(1));
        let m = model.clone();
        let p = poison.clone();
        let cfg = ShardedConfig::new(shards, workers, BATCH, DIMS[0], CLASSES);
        let err = ShardedServer::serve_all(
            cfg,
            move |xs: &[f32]| {
                assert!(
                    xs.chunks(DIMS[0]).all(|row| row != &p[..]),
                    "poison request in batch"
                );
                m.forward(xs, BATCH)
            },
            imgs.clone(),
        )
        .unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("8 of 40"), "{msg}");
        assert!(msg.contains("panicked"), "{msg}");
    }
}
