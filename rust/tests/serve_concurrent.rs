//! Concurrent-serving integration: the multi-worker server over the
//! synthetic DSG model (real column-skipping engines, no artifacts
//! needed) must produce bit-identical predictions for ANY worker count
//! and ANY intra-op thread budget on the same pre-enqueued load, while
//! preserving FIFO ids and the padding semantics of the baseline pump.

use dsg::serve::{Batcher, ConcurrentServer, Queue, RejectReason, ServeReport, ServerConfig, SynthModel};
use std::sync::Arc;
use std::time::{Duration, Instant};

const DIMS: &[usize] = &[64, 96, 80];
const CLASSES: usize = 10;
const BATCH: usize = 8;
const GAMMA: f32 = 0.7;

fn images(n: usize) -> Vec<Vec<f32>> {
    let m = SynthModel::new(1, DIMS, CLASSES, GAMMA);
    (0..n).map(|i| m.synth_image(500 + i as u64)).collect()
}

fn run_serve(workers: usize, intra: usize, imgs: &[Vec<f32>]) -> ServeReport {
    let model = Arc::new(SynthModel::new(1, DIMS, CLASSES, GAMMA).with_intra_threads(intra));
    let cfg = ServerConfig::new(workers, BATCH, DIMS[0], CLASSES)
        .with_max_wait(Duration::from_millis(5));
    // serve_all: the whole load is enqueued before workers spawn, so
    // batch boundaries — and DSG masks — are timing-independent
    ConcurrentServer::serve_all(cfg, move |xs: &[f32]| model.forward(xs, BATCH), imgs.to_vec())
        .unwrap()
}

#[test]
fn predictions_identical_across_worker_counts() {
    let imgs = images(50);
    let base = run_serve(1, 1, &imgs);
    assert_eq!(base.served, 50);
    for (workers, intra) in [(2usize, 2usize), (4, 1), (4, 3)] {
        let got = run_serve(workers, intra, &imgs);
        assert_eq!(got.served, 50);
        assert_eq!(
            base.predictions(),
            got.predictions(),
            "{workers} workers x {intra} threads diverged from 1x1"
        );
    }
}

#[test]
fn concurrent_matches_baseline_pump() {
    // Same model, same load: the multi-worker server and the retained
    // single-threaded pump must agree bit-for-bit on every prediction.
    let imgs = images(37);
    let conc = run_serve(4, 2, &imgs);

    let model = SynthModel::new(1, DIMS, CLASSES, GAMMA);
    let mut q = Queue::new();
    for img in &imgs {
        q.push(img.clone());
    }
    let mut b = Batcher::new(BATCH, DIMS[0], CLASSES);
    let baseline = b.pump(&mut q, |xs| model.forward(xs, BATCH)).unwrap();

    assert_eq!(conc.served, baseline.len());
    assert_eq!(conc.padded_slots, b.stats.padded_slots);
    for (c, s) in conc.responses.iter().zip(&baseline) {
        assert_eq!(c.id, s.id);
        assert_eq!(c.pred, s.pred, "request {} diverged", c.id);
    }
}

#[test]
fn panic_mid_batch_does_not_deadlock_serve_all() {
    // A real model wrapped with a poison trip-wire: the batch holding
    // request 12 panics mid-flight.  serve_all must drain everything
    // else and return an error — not hang on a dead worker — for any
    // worker count (the shutdown/drain race this test pins down).
    let imgs = images(40);
    let poison = imgs[12].clone();
    for workers in [1usize, 4] {
        let model =
            Arc::new(SynthModel::new(1, DIMS, CLASSES, GAMMA).with_intra_threads(1));
        let m = model.clone();
        let p = poison.clone();
        let cfg = ServerConfig::new(workers, BATCH, DIMS[0], CLASSES)
            .with_max_wait(Duration::from_millis(5));
        let t0 = Instant::now();
        let err = ConcurrentServer::serve_all(
            cfg,
            move |xs: &[f32]| {
                assert!(
                    xs.chunks(DIMS[0]).all(|row| row != &p[..]),
                    "poison request in batch"
                );
                m.forward(xs, BATCH)
            },
            imgs.clone(),
        )
        .unwrap_err();
        assert!(t0.elapsed() < Duration::from_secs(30), "serve_all hung after a panic");
        let msg = err.to_string();
        assert!(msg.contains("failed"), "{msg}");
        assert!(msg.contains("panicked"), "{msg}");
    }
}

#[test]
fn over_capacity_requests_get_explicit_rejection() {
    // Slow forward + tiny cap: a fast burst must split into admitted
    // (all served) and rejected (answered NOW with Overloaded) — no
    // silent drops, no unbounded queue growth.
    let model = Arc::new(SynthModel::new(1, DIMS, CLASSES, GAMMA).with_intra_threads(1));
    let m = model.clone();
    let cfg = ServerConfig::new(1, BATCH, DIMS[0], CLASSES)
        .with_queue_cap(4)
        .with_max_wait(Duration::from_millis(1));
    let srv = ConcurrentServer::start(cfg, move |xs: &[f32]| {
        std::thread::sleep(Duration::from_millis(10));
        m.forward(xs, BATCH)
    });
    let imgs = images(80);
    let mut admitted = 0usize;
    let mut rejected = 0usize;
    for img in imgs {
        match srv.try_submit(img) {
            Ok(_) => admitted += 1,
            Err(r) => {
                assert_eq!(r.reason, RejectReason::Overloaded);
                rejected += 1;
            }
        }
    }
    assert!(rejected > 0, "an 80-request burst past a 4-slot cap must reject");
    let report = srv.shutdown().unwrap();
    assert_eq!(report.served, admitted, "admitted + rejected must conserve the burst");
    assert_eq!(report.served + rejected, 80);
}

#[test]
fn report_accounting_is_consistent() {
    let imgs = images(45); // 45 = 5*8 + 5 -> 6 batches, 3 padded
    let report = run_serve(3, 1, &imgs);
    assert_eq!(report.served, 45);
    assert_eq!(report.batches, 6);
    assert_eq!(report.padded_slots, 3);
    assert_eq!(report.latency.count(), 45);
    assert_eq!(report.compute.count(), 6); // one sample per batch
    assert_eq!(report.responses.len(), 45);
    assert!(report.wall > 0.0);
    assert!(report.throughput() > 0.0);
    // per-worker stats sum to the totals
    let sum: usize = report.per_worker.iter().map(|w| w.served).sum();
    assert_eq!(sum, 45);
    // every request id present exactly once, in order
    for (i, r) in report.responses.iter().enumerate() {
        assert_eq!(r.id, i as u64);
        assert!(r.latency >= r.compute - 1e-9, "latency includes compute");
    }
}
