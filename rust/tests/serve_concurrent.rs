//! Concurrent-serving integration: the multi-worker server over the
//! synthetic DSG model (real column-skipping engines, no artifacts
//! needed) must produce bit-identical predictions for ANY worker count
//! and ANY intra-op thread budget on the same pre-enqueued load, while
//! preserving FIFO ids and the padding semantics of the baseline pump.

use dsg::serve::{Batcher, ConcurrentServer, Queue, ServeReport, ServerConfig, SynthModel};
use std::sync::Arc;
use std::time::Duration;

const DIMS: &[usize] = &[64, 96, 80];
const CLASSES: usize = 10;
const BATCH: usize = 8;
const GAMMA: f32 = 0.7;

fn images(n: usize) -> Vec<Vec<f32>> {
    let m = SynthModel::new(1, DIMS, CLASSES, GAMMA);
    (0..n).map(|i| m.synth_image(500 + i as u64)).collect()
}

fn run_serve(workers: usize, intra: usize, imgs: &[Vec<f32>]) -> ServeReport {
    let model = Arc::new(SynthModel::new(1, DIMS, CLASSES, GAMMA).with_intra_threads(intra));
    let cfg = ServerConfig::new(workers, BATCH, DIMS[0], CLASSES)
        .with_max_wait(Duration::from_millis(5));
    // serve_all: the whole load is enqueued before workers spawn, so
    // batch boundaries — and DSG masks — are timing-independent
    ConcurrentServer::serve_all(cfg, move |xs: &[f32]| model.forward(xs, BATCH), imgs.to_vec())
        .unwrap()
}

#[test]
fn predictions_identical_across_worker_counts() {
    let imgs = images(50);
    let base = run_serve(1, 1, &imgs);
    assert_eq!(base.served, 50);
    for (workers, intra) in [(2usize, 2usize), (4, 1), (4, 3)] {
        let got = run_serve(workers, intra, &imgs);
        assert_eq!(got.served, 50);
        assert_eq!(
            base.predictions(),
            got.predictions(),
            "{workers} workers x {intra} threads diverged from 1x1"
        );
    }
}

#[test]
fn concurrent_matches_baseline_pump() {
    // Same model, same load: the multi-worker server and the retained
    // single-threaded pump must agree bit-for-bit on every prediction.
    let imgs = images(37);
    let conc = run_serve(4, 2, &imgs);

    let model = SynthModel::new(1, DIMS, CLASSES, GAMMA);
    let mut q = Queue::new();
    for img in &imgs {
        q.push(img.clone());
    }
    let mut b = Batcher::new(BATCH, DIMS[0], CLASSES);
    let baseline = b.pump(&mut q, |xs| model.forward(xs, BATCH)).unwrap();

    assert_eq!(conc.served, baseline.len());
    assert_eq!(conc.padded_slots, b.stats.padded_slots);
    for (c, s) in conc.responses.iter().zip(&baseline) {
        assert_eq!(c.id, s.id);
        assert_eq!(c.pred, s.pred, "request {} diverged", c.id);
    }
}

#[test]
fn report_accounting_is_consistent() {
    let imgs = images(45); // 45 = 5*8 + 5 -> 6 batches, 3 padded
    let report = run_serve(3, 1, &imgs);
    assert_eq!(report.served, 45);
    assert_eq!(report.batches, 6);
    assert_eq!(report.padded_slots, 3);
    assert_eq!(report.latency.count(), 45);
    assert_eq!(report.compute.count(), 6); // one sample per batch
    assert_eq!(report.responses.len(), 45);
    assert!(report.wall > 0.0);
    assert!(report.throughput() > 0.0);
    // per-worker stats sum to the totals
    let sum: usize = report.per_worker.iter().map(|w| w.served).sum();
    assert_eq!(sum, 45);
    // every request id present exactly once, in order
    for (i, r) in report.responses.iter().enumerate() {
        assert_eq!(r.id, i as u64);
        assert!(r.latency >= r.compute - 1e-9, "latency includes compute");
    }
}
