//! SIMD/scalar parity for the `--kernels simd` mode.
//!
//! The default kernels are bit-exact; `SparseKernels::Simd` is the one
//! explicitly relaxed mode.  These tests pin the relaxation to exactly
//! the documented surface (docs/ARCHITECTURE.md, "Kernel dispatch & ISA
//! detection"):
//!
//! * forward dot products (dense and gathered) may diverge from the
//!   scalar contract, bounded by `4 * d * EPS * sum(|x_q * w_q|)` per
//!   output element, and are bit-exact for `d < 8` on the dense dot;
//! * the backward (dX, gradW) and the ZVC bitmask/count pass are
//!   bit-identical on every kernel table;
//! * a host without AVX2+FMA (or `DSG_SIMD=off`) routes `--kernels simd`
//!   to the scalar table itself — forced fallback is bit-exact by
//!   construction, which the pointer-identity test proves.
//!
//! On a non-AVX2 host the ULP tests still run: both tables are the
//! scalar table and the bound holds trivially at zero divergence.

use dsg::drs::topk::{self, RowMask};
use dsg::serve::SynthModel;
use dsg::sparse::parallel::{self, active_kernels, scalar_kernels, NzIndex, SparseKernels};
use dsg::sparse::simd::{self, Isa};
use dsg::tensor::Tensor;
use dsg::util::Pcg32;
use dsg::zvc;

/// Adversarial value stream: exact zeros, negative zero, subnormals,
/// large/small magnitudes and sign flips (catastrophic-cancellation
/// bait), from a deterministic generator.
fn adversarial_vec(rng: &mut Pcg32, len: usize) -> Vec<f32> {
    (0..len)
        .map(|i| match i % 11 {
            0 => 0.0,
            1 => -0.0,
            2 => f32::MIN_POSITIVE / 8.0,
            3 => -f32::MIN_POSITIVE / 2.0,
            4 => 1e6 * rng.uniform_in(-1.0, 1.0),
            5 => 1e-6 * rng.uniform_in(-1.0, 1.0),
            _ => rng.uniform_in(-2.0, 2.0),
        })
        .collect()
}

/// The documented per-element divergence bound for a width-`d` dot.
fn ulp_bound(x: &[f32], w: &[f32], d: usize) -> f64 {
    let mag: f64 = (0..d).map(|q| (x[q] as f64 * w[q] as f64).abs()).sum();
    4.0 * d as f64 * f32::EPSILON as f64 * mag + f32::MIN_POSITIVE as f64
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn forced_fallback_is_the_scalar_table() {
    // every non-Simd mode dispatches on the scalar table, always
    assert!(std::ptr::eq(SparseKernels::Compound.table(), scalar_kernels()));
    assert!(std::ptr::eq(SparseKernels::OutputSparse.table(), scalar_kernels()));
    assert_eq!(scalar_kernels().isa, Isa::Scalar);
    // when the probe (or DSG_SIMD=off) says scalar, Simd mode IS the
    // scalar table — same static, so bit-exactness needs no further proof
    if simd::active_isa() == Isa::Scalar {
        assert!(std::ptr::eq(SparseKernels::Simd.table(), scalar_kernels()));
    } else {
        assert_eq!(SparseKernels::Simd.table().isa, Isa::Avx2Fma);
    }
    // the pure override rules behind DSG_SIMD, independent of process env
    for raw in ["off", "scalar", "0"] {
        assert_eq!(
            simd::isa_from_env(Some(raw), Isa::Avx2Fma),
            (Isa::Scalar, None),
            "DSG_SIMD={raw} must force scalar"
        );
    }
    let (isa, warn) = simd::isa_from_env(Some("bogus"), Isa::Avx2Fma);
    assert_eq!(isa, Isa::Avx2Fma);
    assert!(warn.expect("junk value must warn").contains("DSG_SIMD"));
}

#[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
#[test]
fn avx2_dot_within_ulp_bound_and_exact_below_lane_width() {
    use dsg::sparse::parallel::ScalarPrims;
    use dsg::sparse::simd::{Avx2Prims, Prims};
    if simd::detected_isa() != Isa::Avx2Fma {
        return; // no vector unit to compare against
    }
    let mut rng = Pcg32::seeded(41);
    for d in [0usize, 1, 3, 4, 5, 7, 8, 9, 12, 15, 16, 17, 31, 32, 33, 100, 257] {
        let x = adversarial_vec(&mut rng, d);
        let w = adversarial_vec(&mut rng, d);
        let s = ScalarPrims::dot(&x, &w, d);
        let v = Avx2Prims::dot(&x, &w, d);
        if d < 8 {
            // vector loop never runs: the tail IS the scalar contract
            assert_eq!(s.to_bits(), v.to_bits(), "dot must be bit-exact at d={d}");
        } else {
            let err = (s as f64 - v as f64).abs();
            let bound = ulp_bound(&x, &w, d);
            assert!(err <= bound, "dot d={d}: |{s} - {v}| = {err} > bound {bound}");
        }
    }
}

#[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
#[test]
fn avx2_dot_sparse_skips_masked_lanes_and_stays_bounded() {
    use dsg::sparse::parallel::ScalarPrims;
    use dsg::sparse::simd::{Avx2Prims, Prims};
    if simd::detected_isa() != Isa::Avx2Fma {
        return;
    }
    let mut rng = Pcg32::seeded(43);
    for d in [16usize, 33, 100, 257] {
        let mut x = adversarial_vec(&mut rng, d);
        let mut w = adversarial_vec(&mut rng, d);
        // gathered coordinates: every third lane, plus a ragged tail so
        // nz.len() is not a multiple of 8
        let nz: Vec<u32> = (0..d as u32).filter(|q| q % 3 != 1).collect();
        // poison every coordinate OUTSIDE the gather list: the kernels
        // must never read them (NaN would otherwise reach the result)
        for q in 0..d as u32 {
            if !nz.contains(&q) {
                x[q as usize] = f32::NAN;
                w[q as usize] = f32::NAN;
            }
        }
        let clean =
            |q: u32| -> (f32, f32) { (x[q as usize], w[q as usize]) };
        let mag: f64 = nz
            .iter()
            .map(|&q| {
                let (a, b) = clean(q);
                (a as f64 * b as f64).abs()
            })
            .sum();
        let s = ScalarPrims::dot_sparse(&nz, &x, &w, d);
        let v = Avx2Prims::dot_sparse(&nz, &x, &w, d);
        assert!(s.is_finite(), "scalar read a poisoned lane at d={d}");
        assert!(v.is_finite(), "simd read a poisoned lane at d={d}");
        let bound = 4.0 * d as f64 * f32::EPSILON as f64 * mag + f32::MIN_POSITIVE as f64;
        let err = (s as f64 - v as f64).abs();
        assert!(err <= bound, "dot_sparse d={d}: err {err} > bound {bound}");
        // empty gather list: nothing to reassociate
        assert_eq!(
            ScalarPrims::dot_sparse(&[], &x, &w, d).to_bits(),
            Avx2Prims::dot_sparse(&[], &x, &w, d).to_bits()
        );
    }
}

/// Forward entry points, active table vs scalar table, both mask
/// layouts, both density bands: every selected output within the ULP
/// bound, every unselected output bit-identical between tables (the
/// kernels zero them the same way), NaN in never-selected weight columns
/// never contaminating a result.
#[test]
fn forward_entries_active_vs_scalar_within_ulp() {
    let (m, d, n) = (13, 37, 24);
    let mut rng = Pcg32::seeded(47);
    let x = adversarial_vec(&mut rng, m * d);
    let mut w = adversarial_vec(&mut rng, n * d); // (n, d) transposed layout
    let virt = Tensor::new(&[m, n], rng.normal_vec(m * n, 1.0));

    let mut masks: Vec<RowMask> = Vec::new();
    masks.push(topk::select_rowmask(&virt, 0.6)); // unstructured CSR
    let mut fixed = RowMask::new();
    fixed.fill_topk(virt.data(), m, n, 7, &mut Vec::new()); // packed FixedK
    masks.push(fixed);

    // poison a weight column no mask selects; selection is per-mask, so
    // find a column unselected in BOTH (fall back to none if all used)
    'poison: for j in 0..n {
        for mask in &masks {
            for i in 0..m {
                if mask.row(i).contains(&(j as u32)) {
                    continue 'poison;
                }
            }
        }
        for q in 0..d {
            w[j * d + q] = f32::NAN;
        }
        break;
    }

    for mask in &masks {
        for in_density in [1.0f32, 0.05] {
            let mut scalar_out = vec![0.0f32; m * n];
            let mut simd_out = vec![0.0f32; m * n];
            let r_s = parallel::dsg_vmm_compound_parallel_into_kt(
                scalar_kernels(),
                &x,
                m,
                d,
                &w,
                n,
                mask,
                in_density,
                3,
                &mut scalar_out,
            );
            let r_v = parallel::dsg_vmm_compound_parallel_into_kt(
                active_kernels(),
                &x,
                m,
                d,
                &w,
                n,
                mask,
                in_density,
                3,
                &mut simd_out,
            );
            assert_eq!(r_s, r_v, "realized-op counts are mode-independent");
            for i in 0..m {
                let sel = mask.row(i);
                for j in 0..n {
                    let (a, b) = (scalar_out[i * n + j], simd_out[i * n + j]);
                    if sel.contains(&(j as u32)) {
                        assert!(a.is_finite() && b.is_finite(), "NaN leak at ({i},{j})");
                        let bound = ulp_bound(&x[i * d..(i + 1) * d], &w[j * d..(j + 1) * d], d);
                        let err = (a as f64 - b as f64).abs();
                        assert!(
                            err <= bound,
                            "({i},{j}) density {in_density}: err {err} > bound {bound}"
                        );
                    } else {
                        assert_eq!(a.to_bits(), b.to_bits(), "unselected ({i},{j}) must match");
                    }
                }
            }
        }
    }
}

/// The backward family is bit-exact on EVERY table (axpy has independent
/// slots and uses separate mul+add in SIMD): dX and gradW from the
/// active table must equal the scalar table to the bit, both layouts,
/// plain and compound entries.
#[test]
fn backward_and_gradw_bit_exact_on_active_table() {
    let (m, d, n) = (11, 41, 18);
    let mut rng = Pcg32::seeded(53);
    let x = adversarial_vec(&mut rng, m * d);
    let w = adversarial_vec(&mut rng, n * d);
    let mut dy = adversarial_vec(&mut rng, m * n);
    // exact-zero gradients exercise the g == 0 skip branches
    for i in (0..dy.len()).step_by(5) {
        dy[i] = 0.0;
    }
    let virt = Tensor::new(&[m, n], rng.normal_vec(m * n, 1.0));
    let mut masks: Vec<RowMask> = vec![topk::select_rowmask(&virt, 0.5)];
    let mut fixed = RowMask::new();
    fixed.fill_topk(virt.data(), m, n, 5, &mut Vec::new());
    masks.push(fixed);
    let mut nzx = NzIndex::new();
    nzx.fill_from_rows(&x, m, d);

    for mask in &masks {
        let (mut dx_s, mut dx_v) = (vec![0.0f32; m * d], vec![0.0f32; m * d]);
        parallel::dsg_vmm_rowmask_backward_parallel_into_kt(
            scalar_kernels(),
            &dy,
            m,
            d,
            &w,
            n,
            mask,
            2,
            &mut dx_s,
        );
        parallel::dsg_vmm_rowmask_backward_parallel_into_kt(
            active_kernels(),
            &dy,
            m,
            d,
            &w,
            n,
            mask,
            2,
            &mut dx_v,
        );
        assert_eq!(bits(&dx_s), bits(&dx_v), "plain dX must be bit-exact");

        dx_s.iter_mut().for_each(|v| *v = 0.0);
        dx_v.iter_mut().for_each(|v| *v = 0.0);
        let c_s = parallel::dsg_vmm_rowmask_backward_compound_parallel_into_kt(
            scalar_kernels(),
            &dy,
            m,
            d,
            &w,
            n,
            mask,
            2,
            &mut dx_s,
        );
        let c_v = parallel::dsg_vmm_rowmask_backward_compound_parallel_into_kt(
            active_kernels(),
            &dy,
            m,
            d,
            &w,
            n,
            mask,
            2,
            &mut dx_v,
        );
        assert_eq!(c_s, c_v);
        assert_eq!(bits(&dx_s), bits(&dx_v), "compound dX must be bit-exact");

        let (mut gw_s, mut gw_v) = (vec![0.0f32; n * d], vec![0.0f32; n * d]);
        parallel::dsg_vmm_rowmask_gradw_parallel_into_kt(
            scalar_kernels(),
            &x,
            &dy,
            m,
            d,
            n,
            mask,
            2,
            &mut gw_s,
        );
        parallel::dsg_vmm_rowmask_gradw_parallel_into_kt(
            active_kernels(),
            &x,
            &dy,
            m,
            d,
            n,
            mask,
            2,
            &mut gw_v,
        );
        assert_eq!(bits(&gw_s), bits(&gw_v), "plain gradW must be bit-exact");

        gw_s.iter_mut().for_each(|v| *v = 0.0);
        gw_v.iter_mut().for_each(|v| *v = 0.0);
        let g_s = parallel::dsg_vmm_rowmask_gradw_compound_parallel_into_kt(
            scalar_kernels(),
            &x,
            &dy,
            m,
            d,
            n,
            mask,
            &nzx,
            2,
            &mut gw_s,
        );
        let g_v = parallel::dsg_vmm_rowmask_gradw_compound_parallel_into_kt(
            active_kernels(),
            &x,
            &dy,
            m,
            d,
            n,
            mask,
            &nzx,
            2,
            &mut gw_v,
        );
        assert_eq!(g_s, g_v);
        assert_eq!(bits(&gw_s), bits(&gw_v), "compound gradW must be bit-exact");
    }
}

/// `d < 8` means the AVX2 dot's vector loop never runs: the whole
/// forward is bit-exact even in Simd mode.
#[test]
fn forward_below_lane_width_bit_exact() {
    let (m, d, n) = (9, 7, 12);
    let mut rng = Pcg32::seeded(59);
    let x = adversarial_vec(&mut rng, m * d);
    let w = adversarial_vec(&mut rng, n * d);
    let virt = Tensor::new(&[m, n], rng.normal_vec(m * n, 1.0));
    let mask = topk::select_rowmask(&virt, 0.4);
    let (mut a, mut b) = (vec![0.0f32; m * n], vec![0.0f32; m * n]);
    parallel::dsg_vmm_rowmask_parallel_into_kt(scalar_kernels(), &x, m, d, &w, n, &mask, 2, &mut a);
    parallel::dsg_vmm_rowmask_parallel_into_kt(active_kernels(), &x, m, d, &w, n, &mask, 2, &mut b);
    assert_eq!(bits(&a), bits(&b), "d < 8 forward must be bit-exact");
}

/// Degenerate selections: k = 0 FixedK masks and fully-empty CSR rows
/// produce identical (all-zero / untouched) outputs on every table.
#[test]
fn degenerate_masks_identical_across_tables() {
    let (m, d, n) = (6, 19, 10);
    let mut rng = Pcg32::seeded(61);
    let x = adversarial_vec(&mut rng, m * d);
    let w = adversarial_vec(&mut rng, n * d);
    let virt: Vec<f32> = rng.normal_vec(m * n, 1.0);

    let mut k0 = RowMask::new();
    k0.fill_topk(&virt, m, n, 0, &mut Vec::new());
    let mut empty = RowMask::new();
    empty.fill_from_threshold(&virt, m, n, f32::INFINITY);

    for mask in [&k0, &empty] {
        assert_eq!(mask.selected(), 0);
        let (mut a, mut b) = (vec![9.0f32; m * n], vec![9.0f32; m * n]);
        parallel::dsg_vmm_rowmask_parallel_into_kt(
            scalar_kernels(),
            &x,
            m,
            d,
            &w,
            n,
            mask,
            2,
            &mut a,
        );
        parallel::dsg_vmm_rowmask_parallel_into_kt(
            active_kernels(),
            &x,
            m,
            d,
            &w,
            n,
            mask,
            2,
            &mut b,
        );
        assert_eq!(bits(&a), bits(&b), "degenerate forward must match");
        let (mut dxa, mut dxb) = (vec![0.0f32; m * d], vec![0.0f32; m * d]);
        let dy = vec![1.0f32; m * n];
        parallel::dsg_vmm_rowmask_backward_parallel_into_kt(
            scalar_kernels(),
            &dy,
            m,
            d,
            &w,
            n,
            mask,
            2,
            &mut dxa,
        );
        parallel::dsg_vmm_rowmask_backward_parallel_into_kt(
            active_kernels(),
            &dy,
            m,
            d,
            &w,
            n,
            mask,
            2,
            &mut dxb,
        );
        assert_eq!(bits(&dxa), bits(&dxb), "degenerate backward must match");
        assert!(dxa.iter().all(|v| *v == 0.0), "no selection => zero dX");
    }
}

/// The ZVC bitmask/count pass is bit-identical on every table: same
/// bytes, same counts, same packed values — NaN counts as nonzero, ±0.0
/// as zero — on both sides of the serial/parallel threshold.
#[test]
fn zvc_bitmask_parity_across_tables() {
    let mut rng = Pcg32::seeded(67);
    // > 2 * PAR_MIN_ELEMS (16 * 1024): threads=4 takes the chunked path;
    // the +5 tail exercises the ragged final mask byte
    for len in [96usize, 40 * 1024 + 5] {
        let mut xs = adversarial_vec(&mut rng, len);
        xs[len / 2] = f32::NAN; // NaN is nonzero to the codec
        let mut serial = zvc::Compressed::new();
        zvc::compress_into(&xs, &mut serial);
        for table in [scalar_kernels(), active_kernels()] {
            let mut c = zvc::Compressed::new();
            zvc::compress_parallel_into_bm(&xs, 4, table.zvc_bitmask, &mut c);
            assert_eq!(c.n, serial.n);
            assert_eq!(c.bitmask, serial.bitmask, "mask bytes ({})", table.isa.label());
            assert_eq!(bits(&c.values), bits(&serial.values), "{}", table.isa.label());
        }
        // the win-gated twin agrees on the nnz measurement
        let mut c = zvc::Compressed::new();
        let r_s =
            zvc::compress_parallel_into_if_smaller_bm(&xs, 4, scalar_kernels().zvc_bitmask, &mut c);
        let mut c2 = zvc::Compressed::new();
        let r_v =
            zvc::compress_parallel_into_if_smaller_bm(&xs, 4, active_kernels().zvc_bitmask, &mut c2);
        assert_eq!(r_s, r_v);
    }
}

/// Engine-level smoke: a SynthModel in Simd mode serves finite logits
/// close to the scalar model's (bitwise-equal when the active ISA is
/// scalar — the forced-fallback path).
#[test]
fn synth_model_simd_mode_smoke() {
    let base = SynthModel::new(3, &[64, 96, 80], 10, 0.7);
    let xs = base.synth_image(11).repeat(4);
    let a = base.forward(&xs, 4).unwrap();
    let b = SynthModel::new(3, &[64, 96, 80], 10, 0.7)
        .with_kernels(SparseKernels::Simd)
        .forward(&xs, 4)
        .unwrap();
    assert_eq!(a.len(), b.len());
    if simd::active_isa() == Isa::Scalar {
        assert_eq!(bits(&a), bits(&b), "forced fallback must serve identical bits");
    } else {
        for (i, (s, v)) in a.iter().zip(&b).enumerate() {
            assert!(v.is_finite(), "logit {i} not finite under simd");
            assert!(
                (s - v).abs() <= 1e-3 * (1.0 + s.abs()),
                "logit {i}: scalar {s} vs simd {v}"
            );
        }
    }
}
