//! Runtime integration: load real HLO artifacts through PJRT, execute,
//! and compare against golden vectors produced by the python side.
//!
//! These tests need the `xla` feature AND `make artifacts`; when either
//! is absent they SKIP (early-return with a note) rather than fail, so
//! the offline tier-1 run stays green.  They are the cross-language
//! proof that the rust coordinator and the JAX/Pallas compute agree.

use dsg::runtime::{golden, Golden, HostTensor, Meta, Runtime};

/// The artifacts dir, or `None` (skip) without PJRT or artifacts.
fn artifacts() -> Option<std::path::PathBuf> {
    if !cfg!(feature = "xla") {
        eprintln!("skipping: dsg built without the `xla` feature");
        return None;
    }
    let d = dsg::artifacts_dir();
    if !d.join("index.json").exists() {
        eprintln!("skipping: artifacts not built — run `make artifacts` first (looked in {d:?})");
        return None;
    }
    Some(d)
}

#[test]
fn kernel_masked_matmul_matches_python_golden() {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::cpu().unwrap();
    let exe = rt.load(&dir.join("kernels/masked_matmul.hlo.txt")).unwrap();
    let g = Golden::load(&dir.join("kernels/masked_matmul")).unwrap();
    let x = g.get("x").unwrap();
    let w = g.get("w").unwrap();
    let mask = g.get("mask").unwrap();
    let want = g.get("out").unwrap();
    let outs = exe.run(&[x.clone(), w.clone(), mask.clone()]).unwrap();
    assert_eq!(outs.len(), 1);
    let diff = golden::max_abs_diff(&outs[0], want);
    assert!(diff < 1e-4, "pallas masked_matmul mismatch: {diff}");
}

#[test]
fn mlp_train_step_matches_python_golden() {
    // Full cross-language check: 29 inputs -> 24 outputs, exact layout.
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::cpu().unwrap();
    let meta = Meta::load(&dir, "mlp").unwrap();
    let exe = rt.load_artifact(&meta, "train").unwrap();
    let g = Golden::load(&dir.join("golden/mlp_step")).unwrap();
    let ins: Vec<HostTensor> = g.with_prefix("in").into_iter().cloned().collect();
    let ins = meta.filter_kept("train", ins);
    let wants = g.with_prefix("out");
    let outs = exe.run(&ins).unwrap();
    assert_eq!(outs.len(), wants.len(), "output arity");
    let mut worst = (0.0f32, String::new());
    for (i, (got, want)) in outs.iter().zip(&wants).enumerate() {
        assert_eq!(got.shape(), want.shape(), "output {i} shape");
        let d = golden::max_abs_diff(got, want);
        if d > worst.0 {
            worst = (d, format!("out{i}"));
        }
    }
    assert!(
        worst.0 < 5e-3,
        "rust-executed train step diverges from python golden at {} by {}",
        worst.1,
        worst.0
    );
}

#[test]
fn train_step_is_deterministic() {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::cpu().unwrap();
    let meta = Meta::load(&dir, "mlp").unwrap();
    let exe = rt.load_artifact(&meta, "train").unwrap();
    let g = Golden::load(&dir.join("golden/mlp_step")).unwrap();
    let ins: Vec<HostTensor> = g.with_prefix("in").into_iter().cloned().collect();
    let ins = meta.filter_kept("train", ins);
    let a = exe.run(&ins).unwrap();
    let b = exe.run(&ins).unwrap();
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(golden::max_abs_diff(x, y), 0.0);
    }
}

#[test]
fn forward_artifact_runs_and_is_shaped() {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::cpu().unwrap();
    let meta = Meta::load(&dir, "mlp").unwrap();
    let exe = rt.load_artifact(&meta, "forward").unwrap();
    let st = dsg::coordinator::ModelState::init(&meta, 3);
    let mut inputs: Vec<HostTensor> = Vec::new();
    inputs.extend(st.params(&meta).iter().cloned());
    inputs.extend(st.bn(&meta).iter().cloned());
    inputs.extend(st.bn_state(&meta).iter().cloned());
    inputs.extend(st.wps.iter().cloned());
    inputs.extend(st.rs.iter().cloned());
    inputs.push(HostTensor::f32(
        &[meta.batch, 784],
        vec![0.1; meta.batch * 784],
    ));
    inputs.push(HostTensor::scalar_f32(0.5));
    let inputs = meta.filter_kept("forward", inputs);
    let outs = exe.run(&inputs).unwrap();
    assert_eq!(outs[0].shape(), &[meta.batch, meta.classes]);
    // densities come after logits, one per dsg layer
    assert_eq!(outs.len(), 1 + meta.counts.dsg);
}

#[test]
fn project_artifact_shapes_match_meta() {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::cpu().unwrap();
    let meta = Meta::load(&dir, "mlp").unwrap();
    let exe = rt.load_artifact(&meta, "project").unwrap();
    let st = dsg::coordinator::ModelState::init(&meta, 4);
    let mut inputs: Vec<HostTensor> = Vec::new();
    for w in st.dsg_weights(&meta) {
        inputs.push(w.clone());
    }
    inputs.extend(st.rs.iter().cloned());
    let outs = exe.run(&inputs).unwrap();
    assert_eq!(outs.len(), meta.counts.wps);
    for (o, spec) in outs.iter().zip(&meta.wps) {
        assert_eq!(o.shape(), &spec.shape[..]);
    }
}

#[test]
fn project_matches_host_drs_projection() {
    // The HLO projection (Pallas kernel) and the rust host projection
    // (TernaryIndex adds) must agree on the same R and W.
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::cpu().unwrap();
    let meta = Meta::load(&dir, "mlp").unwrap();
    let exe = rt.load_artifact(&meta, "project").unwrap();
    let st = dsg::coordinator::ModelState::init(&meta, 5);
    let mut inputs: Vec<HostTensor> = Vec::new();
    for w in st.dsg_weights(&meta) {
        inputs.push(w.clone());
    }
    inputs.extend(st.rs.iter().cloned());
    let outs = exe.run(&inputs).unwrap();

    // host-side: wp = R W / sqrt(k) for the first dsg layer
    let w0 = st.dsg_weights(&meta)[0];
    let r0 = &st.rs[0];
    let wt = dsg::Tensor::new(w0.shape(), w0.as_f32().unwrap().to_vec());
    let rt_ = dsg::Tensor::new(r0.shape(), r0.as_f32().unwrap().to_vec());
    let want = dsg::drs::project_weights(&rt_, &wt);
    let got = outs[0].as_f32().unwrap();
    let maxdiff = got
        .iter()
        .zip(want.data())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(maxdiff < 1e-3, "hlo vs host projection differ by {maxdiff}");
}

#[test]
fn probe_artifact_returns_masks() {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::cpu().unwrap();
    let meta = Meta::load(&dir, "mlp").unwrap();
    if !meta.has_file("probe") {
        eprintln!("skipping: no probe artifact");
        return;
    }
    let exe = rt.load_artifact(&meta, "probe").unwrap();
    let mut st = dsg::coordinator::ModelState::init(&meta, 6);
    // Wp must be the real projection of the weights, not the zero init.
    let proj = rt.load_artifact(&meta, "project").unwrap();
    let mut pin: Vec<HostTensor> =
        st.dsg_weights(&meta).into_iter().cloned().collect();
    pin.extend(st.rs.iter().cloned());
    st.wps = proj.run(&meta.filter_kept("project", pin)).unwrap();

    let mut inputs: Vec<HostTensor> = Vec::new();
    inputs.extend(st.params(&meta).iter().cloned());
    inputs.extend(st.bn(&meta).iter().cloned());
    inputs.extend(st.bn_state(&meta).iter().cloned());
    inputs.extend(st.wps.iter().cloned());
    inputs.extend(st.rs.iter().cloned());
    let mut rng = dsg::Pcg32::seeded(1);
    inputs.push(HostTensor::f32(
        &[meta.batch, 784],
        rng.normal_vec(meta.batch * 784, 1.0),
    ));
    inputs.push(HostTensor::scalar_f32(0.6));
    let inputs = meta.filter_kept("probe", inputs);
    let outs = exe.run(&inputs).unwrap();
    assert_eq!(outs.len(), 1 + meta.counts.dsg);
    // masks are binary with density ~ 1-gamma
    for mask in &outs[1..] {
        let d = mask.as_f32().unwrap();
        assert!(d.iter().all(|&v| v == 0.0 || v == 1.0));
        let density = d.iter().sum::<f32>() / d.len() as f32;
        assert!(
            (density - 0.4).abs() < 0.15,
            "mask density {density} far from 1-gamma"
        );
    }
}

#[test]
fn all_variants_load_and_parse() {
    let Some(dir) = artifacts() else { return };
    for v in Meta::list_variants(&dir).unwrap() {
        let m = Meta::load(&dir, &v).unwrap();
        assert!(m.batch > 0);
        assert!(m.has_file("train"), "{v} missing train artifact");
        assert!(m.has_file("forward"), "{v} missing forward artifact");
        if m.strategy == "drs" {
            assert!(m.has_file("project"), "{v} drs variant missing project");
            assert_eq!(m.counts.wps, m.counts.dsg);
            assert_eq!(m.dsg_weight_indices.len(), m.counts.dsg);
        }
    }
}
