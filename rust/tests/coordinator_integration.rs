//! Coordinator integration: end-to-end training through the rust
//! orchestrator + PJRT artifacts.  Needs the `xla` feature AND
//! `make artifacts`; SKIPS (early return) when either is absent so the
//! offline tier-1 run stays green.

use dsg::config::{GammaSchedule, RunConfig};
use dsg::coordinator::{checkpoint, Trainer};
use dsg::datasets;
use dsg::runtime::{Meta, Runtime};

fn setup(variant: &str) -> Option<(Runtime, Meta)> {
    if !cfg!(feature = "xla") {
        eprintln!("skipping: dsg built without the `xla` feature");
        return None;
    }
    let dir = dsg::artifacts_dir();
    if !dir.join("index.json").exists() {
        eprintln!("skipping: artifacts not built — run `make artifacts` first");
        return None;
    }
    let rt = Runtime::cpu().unwrap();
    let meta = Meta::load(&dir, variant).unwrap();
    Some((rt, meta))
}

fn tiny_cfg(model: &str, steps: usize) -> RunConfig {
    let mut cfg = RunConfig::preset_for_model(model);
    cfg.steps = steps;
    cfg.eval_every = 0;
    cfg.train_size = 512;
    cfg.test_size = 128;
    cfg
}

#[test]
fn mlp_loss_decreases_over_training() {
    let Some((rt, meta)) = setup("mlp") else { return };
    let cfg = tiny_cfg("mlp", 60);
    let data = datasets::fashion_like(cfg.train_size + cfg.test_size, cfg.seed);
    let (train, test) = data.split(0.2);
    let mut t = Trainer::new(&rt, meta, cfg.seed).unwrap();
    let acc = t.train(&cfg, &train, &test).unwrap();
    let first = t.history.steps[..5].iter().map(|s| s.loss).sum::<f32>() / 5.0;
    let last = t.history.steps[55..].iter().map(|s| s.loss).sum::<f32>() / 5.0;
    assert!(
        last < first * 0.7,
        "loss not decreasing: first5 {first:.3} last5 {last:.3}"
    );
    assert!(acc > 0.3, "eval acc {acc} barely above chance after 60 steps");
}

#[test]
fn densities_track_gamma_through_coordinator() {
    let Some((rt, meta)) = setup("mlp") else { return };
    let mut t = Trainer::new(&rt, meta, 1).unwrap();
    let data = datasets::fashion_like(64, 2);
    let mut it = datasets::BatchIter::new(&data, t.meta.batch, 3);
    for &gamma in &[0.0f32, 0.5, 0.9] {
        let (xs, ys) = it.next_batch();
        let out = t.step(&xs, &ys, gamma, 0.01).unwrap();
        for d in &out.densities {
            if gamma == 0.0 {
                assert_eq!(*d, 1.0, "gamma 0 must keep all");
            } else {
                assert!(
                    (d - (1.0 - gamma)).abs() < 0.15,
                    "gamma {gamma}: density {d}"
                );
            }
        }
    }
}

#[test]
fn projection_refresh_changes_wp_after_updates() {
    let Some((rt, meta)) = setup("mlp") else { return };
    let mut t = Trainer::new(&rt, meta, 1).unwrap();
    let wp_before = t.state.wps[0].clone();
    let data = datasets::fashion_like(128, 4);
    let mut it = datasets::BatchIter::new(&data, t.meta.batch, 5);
    for _ in 0..3 {
        let (xs, ys) = it.next_batch();
        t.step(&xs, &ys, 0.5, 0.05).unwrap();
    }
    // weights moved but wp is stale until refresh
    assert_eq!(t.state.wps[0], wp_before);
    t.refresh_projection().unwrap();
    assert_ne!(t.state.wps[0], wp_before, "refresh must recompute Wp");
}

#[test]
fn dense_variant_trains_without_projection() {
    let Some((rt, meta)) = setup("mlp_dense") else { return };
    assert_eq!(meta.counts.wps, 0);
    let cfg = tiny_cfg("mlp_dense", 20);
    let data = datasets::fashion_like(512, 6);
    let (train, test) = data.split(0.2);
    let mut t = Trainer::new(&rt, meta, 3).unwrap();
    let _ = t.train(&cfg, &train, &test).unwrap();
    assert!(t.history.steps.last().unwrap().loss.is_finite());
}

#[test]
fn gamma_warmup_schedule_is_applied() {
    let Some((rt, meta)) = setup("mlp") else { return };
    let mut cfg = tiny_cfg("mlp", 30);
    cfg.gamma = GammaSchedule::Warmup { target: 0.8, warmup: 20 };
    let data = datasets::fashion_like(512, 7);
    let (train, test) = data.split(0.2);
    let mut t = Trainer::new(&rt, meta, 4).unwrap();
    t.train(&cfg, &train, &test).unwrap();
    // densities early should be high (low gamma), late near 0.2
    let d0 = t.history.steps[1].densities[0];
    let d_late = t.history.steps[29].densities[0];
    assert!(d0 > 0.8, "early density {d0} should be near 1");
    assert!((d_late - 0.2).abs() < 0.15, "late density {d_late} should be ~0.2");
}

#[test]
fn checkpoint_roundtrip_preserves_eval() {
    let Some((rt, meta)) = setup("mlp") else { return };
    let cfg = tiny_cfg("mlp", 25);
    let data = datasets::fashion_like(512, 8);
    let (train, test) = data.split(0.25);
    let mut t = Trainer::new(&rt, meta.clone(), 5).unwrap();
    let acc = t.train(&cfg, &train, &test).unwrap();

    let dir = std::env::temp_dir().join("dsg_int_test");
    std::fs::create_dir_all(&dir).unwrap();
    let p = dir.join("mlp.ckpt");
    checkpoint::save(&p, &t.state).unwrap();

    let mut t2 = Trainer::new(&rt, meta, 99).unwrap(); // different init
    t2.state = checkpoint::load(&p).unwrap();
    let acc2 = t2.evaluate(&test, 0.5).unwrap();
    assert!(
        (acc - acc2).abs() < 1e-6,
        "restored eval {acc2} != trained eval {acc}"
    );
}

#[test]
fn lenet_conv_path_trains() {
    let Some((rt, meta)) = setup("lenet") else { return };
    let cfg = tiny_cfg("lenet", 30);
    let data = datasets::fashion_like(512, 9);
    let (train, test) = data.split(0.2);
    let mut t = Trainer::new(&rt, meta, 6).unwrap();
    t.train(&cfg, &train, &test).unwrap();
    let first = t.history.steps[..5].iter().map(|s| s.loss).sum::<f32>() / 5.0;
    let last = t.history.steps[25..].iter().map(|s| s.loss).sum::<f32>() / 5.0;
    assert!(last < first, "lenet loss not decreasing: {first:.3} -> {last:.3}");
    // conv + dense layers all report densities
    assert_eq!(t.history.steps[0].densities.len(), 4);
}

#[test]
fn wrong_batch_size_is_rejected() {
    let Some((rt, meta)) = setup("mlp") else { return };
    let mut t = Trainer::new(&rt, meta, 1).unwrap();
    let err = t.step(&[0.0; 10], &[0; 2], 0.5, 0.1);
    assert!(err.is_err());
}
