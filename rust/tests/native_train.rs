//! Native-training integration: paper Algorithm 1 end-to-end on the
//! host engine — no PJRT, no artifacts, pure rust.  These are TIER-1
//! tests (they run in every `cargo test`), unlike the artifact-gated
//! `coordinator_integration.rs` twins.
//!
//! Covered: convergence on the synthetic fashion task, per-layer mask
//! densities tracking 1-gamma, gamma = 0 DSG vs dense-mode bit-parity,
//! DMS on/off parity at gamma = 0, finite-difference gradient checks
//! through every unit kind (dense / conv / residual / maxpool / gap /
//! classifier, with BN + double mask active), the `lr_decay_every: 0`
//! regression, checkpoint resume, and the ZVC training tape: multi-epoch
//! bit-parity with the dense tape, measured-vs-analytic tape memory, and
//! compressed-tape checkpoint resume.
//!
//! The data-parallel section at the bottom covers the sharded trainer:
//! bit-identical digests at any shard count, kill-and-resume parity at
//! every all-reduce fault site, torn-frame rejection, straggler
//! deadlines, and lost-shard re-sharding.

use dsg::config::{GammaSchedule, RunConfig};
use dsg::coordinator::{checkpoint, CheckpointDir, ModelState, NativeTrainer, TrainOptions};
use dsg::drs::SelectionMode;
use dsg::datasets;
use dsg::util::faults::{self, FaultKind, FaultPlan};
use dsg::native::train::{TapeStorage, TrainEngine};
use dsg::native::zoo::{self, ModelSpec};
use dsg::native::Mode;
use dsg::runtime::{Meta, Unit};
use dsg::sparse::parallel::SparseKernels;
use dsg::train::ParallelTrainer;
use dsg::util::Pcg32;
use dsg::zvc;
use std::time::Duration;

fn smoke_spec() -> ModelSpec {
    ModelSpec::custom_mlp("smoke_mlp", &[784, 32], 10, 32)
}

/// A tiny model touching every unit kind the backward supports.
fn tiny_conv_spec() -> ModelSpec {
    ModelSpec {
        name: "tinyconv".into(),
        base_model: "tinyconv".into(),
        input_shape: vec![2, 8, 8],
        classes: 3,
        batch: 4,
        units: vec![
            Unit::Conv { c_in: 2, c_out: 3, ksize: 3, stride: 1, pad: 1 },
            Unit::MaxPool { size: 2 },
            Unit::Residual { c_in: 3, c_out: 4, stride: 2 },
            Unit::GlobalAvgPool,
            Unit::Dense { d_in: 4, d_out: 6 },
            Unit::Classifier { d_in: 6, d_out: 3 },
        ],
        strategy: "drs".into(),
        eps: 0.5,
        double_mask: true,
        use_bn: true,
    }
}

fn batch_for(meta: &Meta, seed: u64) -> (Vec<f32>, Vec<i32>) {
    let mut rng = Pcg32::seeded(seed);
    let x = rng.normal_vec(meta.batch * meta.input_elems(), 1.0);
    let y = (0..meta.batch).map(|_| rng.below(meta.classes as u32) as i32).collect();
    (x, y)
}

#[test]
fn mlp_loss_decreases_over_native_training() {
    let meta = zoo::synth_meta(&smoke_spec()).unwrap();
    let mut cfg = RunConfig::preset_for_model("mlp");
    cfg.steps = 40;
    cfg.eval_every = 0;
    cfg.train_size = 256;
    cfg.test_size = 64;
    cfg.gamma = GammaSchedule::Constant(0.5);
    let data = datasets::fashion_like(cfg.train_size + cfg.test_size, cfg.seed);
    let (train, test) = data.split(0.2);
    let mut t = NativeTrainer::new(meta, cfg.seed).unwrap();
    let acc = t.train(&cfg, &train, &test).unwrap();
    let first = t.history.steps[..5].iter().map(|s| s.loss).sum::<f32>() / 5.0;
    let last = t.history.steps[35..].iter().map(|s| s.loss).sum::<f32>() / 5.0;
    assert!(t.history.steps.iter().all(|s| s.loss.is_finite()));
    assert!(
        last < first * 0.8,
        "loss not decreasing: first5 {first:.3} last5 {last:.3}"
    );
    assert!(acc > 0.2, "eval acc {acc} barely above chance after 40 steps");
    // densities recorded per dsg layer every step
    assert_eq!(t.history.steps[0].densities.len(), 1);
}

#[test]
fn densities_track_one_minus_gamma() {
    // widths of 200: the sample-0 shared-threshold quantile noise on a
    // 40-wide layer can exceed the 0.1 tolerance (verified numerically)
    let spec = ModelSpec::custom_mlp("dens_mlp", &[32, 200, 200], 4, 16);
    let meta = zoo::synth_meta(&spec).unwrap();
    let mut t = NativeTrainer::new(meta, 3).unwrap();
    let (x, y) = batch_for(&t.meta, 5);
    for &gamma in &[0.0f32, 0.5, 0.9] {
        let out = t.step(&x, &y, gamma, 0.01).unwrap();
        assert_eq!(out.densities.len(), 2);
        for (li, &d) in out.densities.iter().enumerate() {
            assert!(
                (d - (1.0 - gamma)).abs() < 0.1,
                "gamma {gamma} layer {li}: density {d}"
            );
        }
        assert!(out.loss.is_finite());
    }
}

#[test]
fn gamma_zero_step_matches_dense_mode_bitwise() {
    // the keep-all mask routes through the SAME kernels as dense mode,
    // so the first training step must agree bit for bit
    let meta = zoo::synth_meta(&smoke_spec()).unwrap();
    let (x, y) = batch_for(&meta, 11);
    let mut dsg = NativeTrainer::new(meta.clone(), 7).unwrap();
    let mut dense = NativeTrainer::new(meta, 7).unwrap().with_mode(Mode::Dense);
    let o1 = dsg.step(&x, &y, 0.0, 0.05).unwrap();
    let o2 = dense.step(&x, &y, 0.0, 0.05).unwrap();
    assert_eq!(o1.loss.to_bits(), o2.loss.to_bits(), "loss diverged");
    assert_eq!(o1.acc, o2.acc);
    for (a, b) in dsg.state.state.iter().zip(&dense.state.state) {
        assert_eq!(a, b, "post-step state diverged");
    }
    // and the gamma-0 densities read 1.0 in both modes
    assert!(o1.densities.iter().all(|&d| d == 1.0));
    assert!(o2.densities.iter().all(|&d| d == 1.0));
}

#[test]
fn dms_on_off_parity_at_gamma_zero() {
    // with a keep-all mask the second (DMS) mask is the identity, so
    // double_mask on/off must agree bit for bit; at gamma > 0 they split
    let mut on = smoke_spec();
    on.name = "dms_on".into();
    let mut off = smoke_spec();
    off.name = "dms_off".into();
    off.double_mask = false;
    let m_on = zoo::synth_meta(&on).unwrap();
    let m_off = zoo::synth_meta(&off).unwrap();
    let (x, y) = batch_for(&m_on, 13);
    let mut t_on = NativeTrainer::new(m_on.clone(), 9).unwrap();
    let mut t_off = NativeTrainer::new(m_off.clone(), 9).unwrap();
    t_on.step(&x, &y, 0.0, 0.05).unwrap();
    t_off.step(&x, &y, 0.0, 0.05).unwrap();
    for (a, b) in t_on.state.state.iter().zip(&t_off.state.state) {
        assert_eq!(a, b, "gamma-0 DMS parity broken");
    }
    let mut t_on = NativeTrainer::new(m_on, 9).unwrap();
    let mut t_off = NativeTrainer::new(m_off, 9).unwrap();
    t_on.step(&x, &y, 0.6, 0.05).unwrap();
    t_off.step(&x, &y, 0.6, 0.05).unwrap();
    assert!(
        t_on.state.state.iter().zip(&t_off.state.state).any(|(a, b)| a != b),
        "double mask had no effect at gamma 0.6"
    );
}

/// Extract the analytic gradient of every leaf from one lr=1,
/// zero-velocity SGD step: v = -g, w' = w + v  =>  g = w - w'.
fn analytic_grads(meta: &Meta, base: &ModelState, x: &[f32], y: &[i32], gamma: f32) -> ModelState {
    let mut engine = TrainEngine::new(meta, base).unwrap();
    let mut stepped = base.clone();
    engine
        .train_step(&mut stepped, x, y, gamma, 1.0, Mode::Dsg)
        .unwrap();
    stepped
}

fn loss_at(meta: &Meta, state: &ModelState, x: &[f32], y: &[i32], gamma: f32) -> f64 {
    let mut engine = TrainEngine::new(meta, state).unwrap();
    let mut probe = state.clone();
    engine
        .train_step(&mut probe, x, y, gamma, 1.0, Mode::Dsg)
        .unwrap()
        .loss as f64
}

/// Central-difference check of dL/dw for the largest-gradient entries of
/// every parameter and BN leaf.
fn finite_difference_check(spec: &ModelSpec, gamma: f32, seed: u64, h: f32) {
    let meta = zoo::synth_meta(spec).unwrap();
    let mut base = ModelState::init(&meta, seed);
    dsg::native::project_host(&meta, &mut base).unwrap();
    let (x, y) = batch_for(&meta, seed ^ 0xfd);
    let stepped = analytic_grads(&meta, &base, &x, &y, gamma);
    let n_state = meta.state.len();
    for li in 0..n_state {
        let name = &meta.state[li].name;
        if name.starts_with("vel.") || name.starts_with("vbn.") || name.starts_with("bn_state.") {
            continue; // velocities/running stats have no loss gradient
        }
        let w0 = base.state[li].as_f32().unwrap();
        let w1 = stepped.state[li].as_f32().unwrap();
        let grads: Vec<f32> = w0.iter().zip(w1).map(|(a, b)| a - b).collect();
        // probe the largest-|g| entry (clear signal) plus a fixed one
        let mut probes = vec![0usize];
        if let Some((mi, _)) = grads
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.abs().total_cmp(&b.1.abs()))
        {
            probes.push(mi);
        }
        for &pi in &probes {
            let g = grads[pi];
            let mut plus = base.clone();
            plus.state[li].as_f32_mut().unwrap()[pi] += h;
            let mut minus = base.clone();
            minus.state[li].as_f32_mut().unwrap()[pi] -= h;
            let fd = ((loss_at(&meta, &plus, &x, &y, gamma)
                - loss_at(&meta, &minus, &x, &y, gamma))
                / (2.0 * h as f64)) as f32;
            assert!(
                (fd - g).abs() < 5e-2 * fd.abs().max(0.1),
                "{name}[{pi}]: analytic {g:.6} vs finite-difference {fd:.6}"
            );
        }
    }
}

#[test]
fn finite_difference_gradients_mlp() {
    let spec = ModelSpec::custom_mlp("fd_mlp", &[6, 5], 3, 4);
    finite_difference_check(&spec, 0.5, 17, 1e-3);
    // dense strategy variant exercises the no-mask path
    let mut dense = ModelSpec::custom_mlp("fd_mlp_dense", &[6, 5], 3, 4);
    dense.strategy = "dense".into();
    finite_difference_check(&dense, 0.0, 18, 1e-3);
}

#[test]
fn finite_difference_gradients_conv_residual() {
    // smaller h: keeps the probe on one side of maxpool/threshold kinks
    finite_difference_check(&tiny_conv_spec(), 0.4, 23, 2e-4);
}

#[test]
fn lr_decay_every_zero_does_not_panic() {
    // regression: `step % cfg.lr_decay_every` used to divide by zero
    let meta = zoo::synth_meta(&ModelSpec::custom_mlp("lr0", &[784, 16], 10, 16)).unwrap();
    let mut cfg = RunConfig::preset_for_model("mlp");
    cfg.steps = 12;
    cfg.eval_every = 0;
    cfg.lr_decay_every = 0;
    cfg.refresh_every = 5; // also exercise the host Wp refresh mid-run
    cfg.train_size = 64;
    cfg.test_size = 32;
    let data = datasets::fashion_like(cfg.train_size + cfg.test_size, cfg.seed);
    let (train, test) = data.split(0.33);
    let mut t = NativeTrainer::new(meta, 1).unwrap();
    let acc = t.train(&cfg, &train, &test).unwrap();
    assert!(acc.is_finite());
    assert_eq!(t.history.steps.len(), 12);
}

#[test]
fn checkpoint_roundtrip_resumes_native_training() {
    let meta = zoo::synth_meta(&ModelSpec::custom_mlp("ckpt", &[784, 16], 10, 16)).unwrap();
    let (x, y) = batch_for(&meta, 29);
    let mut t = NativeTrainer::new(meta.clone(), 4).unwrap();
    t.step(&x, &y, 0.5, 0.05).unwrap();
    let dir = std::env::temp_dir().join("dsg_native_train_test");
    std::fs::create_dir_all(&dir).unwrap();
    let p = dir.join("native.ckpt");
    checkpoint::save(&p, &t.state).unwrap();
    let restored = checkpoint::load(&p).unwrap();
    let mut t2 = NativeTrainer::with_state(meta, restored).unwrap();
    // both continue identically from the same state
    let a = t.step(&x, &y, 0.5, 0.05).unwrap();
    let b = t2.step(&x, &y, 0.5, 0.05).unwrap();
    assert_eq!(a.loss.to_bits(), b.loss.to_bits());
    for (s1, s2) in t.state.state.iter().zip(&t2.state.state) {
        assert_eq!(s1, s2);
    }
}

/// Bit-level equality of every state leaf (stronger than the `==` the
/// other parity tests use: ±0.0 and NaN payloads must match too).
fn assert_state_bits_eq(a: &ModelState, b: &ModelState, what: &str) {
    assert_eq!(a.state.len(), b.state.len(), "{what}: leaf count");
    for (i, (ta, tb)) in a.state.iter().zip(&b.state).enumerate() {
        let fa = ta.as_f32().unwrap();
        let fb = tb.as_f32().unwrap();
        assert_eq!(fa.len(), fb.len(), "{what}: leaf {i} len");
        for (j, (va, vb)) in fa.iter().zip(fb).enumerate() {
            assert_eq!(
                va.to_bits(),
                vb.to_bits(),
                "{what}: leaf {i}[{j}] {va} vs {vb}"
            );
        }
    }
}

#[test]
fn zvc_tape_training_is_bit_identical_multi_epoch() {
    // ZVC is lossless, so compressed-tape training must reproduce the
    // dense tape to the BIT — losses, weights, velocities, and BN
    // running stats — across multiple epochs (12 steps over 2 batches =
    // 6 epochs), at gamma 0 (keep-all) and 0.5.
    for &gamma in &[0.0f32, 0.5] {
        let meta = zoo::synth_meta(&smoke_spec()).unwrap();
        let mut cfg = RunConfig::preset_for_model("mlp");
        cfg.steps = 12;
        cfg.eval_every = 4;
        cfg.train_size = 64;
        cfg.test_size = 32;
        cfg.gamma = GammaSchedule::Constant(gamma);
        let data = datasets::fashion_like(cfg.train_size + cfg.test_size, cfg.seed);
        let (train, test) = data.split(1.0 / 3.0);
        let mut dense = NativeTrainer::new(meta.clone(), 5).unwrap();
        let mut zvc_t = NativeTrainer::new(meta, 5).unwrap().with_tape(TapeStorage::Zvc);
        let acc_a = dense.train(&cfg, &train, &test).unwrap();
        let acc_b = zvc_t.train(&cfg, &train, &test).unwrap();
        assert_eq!(acc_a.to_bits(), acc_b.to_bits(), "gamma {gamma}: eval acc");
        assert_eq!(dense.history.steps.len(), zvc_t.history.steps.len());
        for (a, b) in dense.history.steps.iter().zip(&zvc_t.history.steps) {
            assert_eq!(
                a.loss.to_bits(),
                b.loss.to_bits(),
                "gamma {gamma} step {}: loss diverged",
                a.step
            );
            assert_eq!(a.densities, b.densities, "gamma {gamma} step {}", a.step);
        }
        assert_state_bits_eq(&dense.state, &zvc_t.state, &format!("gamma {gamma}"));
        // the zvc run must have actually compressed something at work
        if gamma > 0.0 {
            let mem = zvc_t.tape_memory();
            assert!(
                mem.peak() < mem.dense_peak(),
                "gamma {gamma}: zvc tape saved nothing ({} vs {})",
                mem.peak(),
                mem.dense_peak()
            );
        }
    }
}

#[test]
fn zvc_tape_bit_parity_on_conv_residual_topology() {
    // same claim through every unit kind the backward supports
    let meta = zoo::synth_meta(&tiny_conv_spec()).unwrap();
    let mut dense = NativeTrainer::new(meta.clone(), 9).unwrap();
    let mut zvc_t = NativeTrainer::new(meta.clone(), 9).unwrap().with_tape(TapeStorage::Zvc);
    for step in 0u64..4 {
        let (x, y) = batch_for(&meta, 40 + step);
        let a = dense.step(&x, &y, 0.5, 0.05).unwrap();
        let b = zvc_t.step(&x, &y, 0.5, 0.05).unwrap();
        assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "step {step}");
    }
    assert_state_bits_eq(&dense.state, &zvc_t.state, "tinyconv");
}

#[test]
fn tape_meter_matches_zvc_accounting() {
    // the measured-vs-analytic cross-check: every compressed activation
    // record's stored bytes ARE zvc_bytes at its measured nnz, the peak
    // is the sum of everything taped, and a dense-tape run of the same
    // step peaks at exactly the zvc run's dense-equivalent accounting
    let meta = zoo::synth_meta(&tiny_conv_spec()).unwrap();
    let (x, y) = batch_for(&meta, 37);
    let mut t = NativeTrainer::new(meta.clone(), 7).unwrap().with_tape(TapeStorage::Zvc);
    t.step(&x, &y, 0.5, 0.05).unwrap();
    let mem = t.tape_memory();
    let stored_sum: u64 = mem.allocs().iter().map(|a| a.stored_bytes).sum();
    assert_eq!(mem.peak(), stored_sum, "everything taped is live at the turnover");
    assert_eq!(mem.live(), 0, "backward must release every record");
    let mut compressed = 0usize;
    for a in mem.allocs() {
        if !a.is_act() {
            continue;
        }
        assert_eq!(a.dense_bytes, 4 * a.elems as u64, "unit {} {}", a.unit, a.part);
        let z = zvc::zvc_bytes_nnz(a.elems, a.nnz) as u64;
        assert_eq!(
            a.stored_bytes,
            z.min(a.dense_bytes),
            "unit {} {}: stored bytes off analytic",
            a.unit,
            a.part
        );
        if a.stored_bytes < a.dense_bytes {
            compressed += 1;
        }
    }
    assert!(compressed >= 4, "only {compressed} activation records compressed");
    let mut td = NativeTrainer::new(meta, 7).unwrap();
    td.step(&x, &y, 0.5, 0.05).unwrap();
    assert_eq!(td.tape_memory().peak(), mem.dense_peak());
    assert_eq!(td.tape_memory().reduction(), 1.0);
}

#[test]
fn structured_training_bit_identical_across_threads() {
    // the structured (constant fan-in) mode carries the same crown
    // jewel as unstructured: any intra-op budget, same bits — through
    // every unit kind, forward AND backward, tape replay included
    for blocked in [false, true] {
        let sel = SelectionMode::Structured { blocked };
        let meta = zoo::synth_meta(&tiny_conv_spec()).unwrap();
        let mut base = NativeTrainer::new(meta.clone(), 9)
            .unwrap()
            .with_threads(1)
            .with_selection(sel);
        let mut losses = Vec::new();
        for step in 0u64..3 {
            let (x, y) = batch_for(&meta, 60 + step);
            losses.push(base.step(&x, &y, 0.5, 0.05).unwrap().loss.to_bits());
        }
        for t in [2usize, 3, 8] {
            let mut tr = NativeTrainer::new(meta.clone(), 9)
                .unwrap()
                .with_threads(t)
                .with_selection(sel);
            for (step, &want) in losses.iter().enumerate() {
                let (x, y) = batch_for(&meta, 60 + step as u64);
                let got = tr.step(&x, &y, 0.5, 0.05).unwrap().loss.to_bits();
                assert_eq!(got, want, "blocked {blocked} threads {t} step {step}");
            }
            assert_state_bits_eq(&base.state, &tr.state, "structured threads");
        }
    }
}

#[test]
fn structured_masks_metered_at_packed_size() {
    // the fig6 meter cross-check for FixedK: a packed mask is charged
    // EXACTLY 4 bytes per stored index (rows*k u32s, no offsets array),
    // while a non-full CSR mask always carries the offsets term on top
    let meta = zoo::synth_meta(&tiny_conv_spec()).unwrap();
    let (x, y) = batch_for(&meta, 37);
    let mut st = NativeTrainer::new(meta.clone(), 7)
        .unwrap()
        .with_selection(SelectionMode::Structured { blocked: false });
    st.step(&x, &y, 0.5, 0.05).unwrap();
    let mut masks = 0usize;
    for a in st.tape_memory().allocs().iter().filter(|a| a.part == "mask") {
        masks += 1;
        assert_eq!(
            a.stored_bytes,
            4 * a.nnz as u64,
            "unit {}: FixedK mask not metered at packed size",
            a.unit
        );
    }
    assert!(masks >= 4, "only {masks} mask records on the tape");
    let mut un = NativeTrainer::new(meta, 7).unwrap();
    un.step(&x, &y, 0.5, 0.05).unwrap();
    for a in un.tape_memory().allocs().iter().filter(|a| a.part == "mask") {
        if a.nnz < a.elems {
            // non-full CSR: 4*nnz indices PLUS the offsets array
            assert!(
                a.stored_bytes > 4 * a.nnz as u64,
                "unit {}: CSR mask missing its offsets accounting",
                a.unit
            );
        }
    }
}

#[test]
fn tiny_gamma_structured_equals_unstructured_bitwise() {
    // drop = floor(gamma*pool) = 0 on every layer: both modes
    // canonicalize to the implicit keep-all mask, so the two selection
    // modes must agree bit for bit — the k = width contract end-to-end
    let meta = zoo::synth_meta(&smoke_spec()).unwrap();
    let (x, y) = batch_for(&meta, 23);
    let mut un = NativeTrainer::new(meta.clone(), 5).unwrap();
    let mut st = NativeTrainer::new(meta, 5)
        .unwrap()
        .with_selection(SelectionMode::Structured { blocked: true });
    let a = un.step(&x, &y, 0.004, 0.05).unwrap();
    let b = st.step(&x, &y, 0.004, 0.05).unwrap();
    assert_eq!(a.loss.to_bits(), b.loss.to_bits());
    assert_state_bits_eq(&un.state, &st.state, "tiny-gamma modes");
}

#[test]
fn measured_reduction_direction_matches_memmodel() {
    // as gamma rises the measured dense/zvc tape ratio must move the way
    // the analytic model predicts: strictly up
    let meta = zoo::synth_meta(&tiny_conv_spec()).unwrap();
    let mut measured = Vec::new();
    for &gamma in &[0.0f32, 0.5, 0.8] {
        let mut t = NativeTrainer::new(meta.clone(), 7).unwrap().with_tape(TapeStorage::Zvc);
        let (x, y) = batch_for(&meta, 51);
        t.step(&x, &y, gamma, 0.05).unwrap();
        measured.push(t.tape_memory().reduction());
    }
    assert!(
        measured.windows(2).all(|w| w[1] > w[0]),
        "measured tape reductions not increasing with gamma: {measured:?}"
    );
    // the analytic model over the same gammas agrees on the direction
    let net = dsg::costmodel::shapes::vgg8(128);
    let analytic: Vec<f64> = [0.0f64, 0.5, 0.8]
        .iter()
        .map(|&g| dsg::memmodel::memory(&net, dsg::memmodel::effective_sparsity(g, 0.5)).train_reduction())
        .collect();
    assert!(analytic.windows(2).all(|w| w[1] > w[0]), "{analytic:?}");
}

#[test]
fn compressed_record_serde_edges() {
    // tape-record payloads through the checkpoint codec: empty tensor
    // and a keep-all (gamma 0) activation where every element survives
    let c = zvc::compress(&[]);
    assert_eq!(c.nnz(), 0);
    assert_eq!(zvc::from_bytes(&zvc::to_bytes(&c)).unwrap(), c);
    let xs: Vec<f32> = (1..=97).map(|i| i as f32).collect();
    let c = zvc::compress(&xs);
    assert_eq!(c.nnz(), 97, "keep-all: every element stored");
    let back = zvc::from_bytes(&zvc::to_bytes(&c)).unwrap();
    assert_eq!(zvc::decompress(&back), xs);
}

#[test]
fn checkpoint_resume_with_zvc_tape_is_bit_exact() {
    // a run checkpointed mid-training resumes bit-exactly under EITHER
    // tape storage — the tape is per-step state, the checkpoint is not
    let meta = zoo::synth_meta(&ModelSpec::custom_mlp("zvc_ckpt", &[784, 16], 10, 16)).unwrap();
    let (x, y) = batch_for(&meta, 33);
    let mut t = NativeTrainer::new(meta.clone(), 4).unwrap().with_tape(TapeStorage::Zvc);
    t.step(&x, &y, 0.5, 0.05).unwrap();
    t.step(&x, &y, 0.5, 0.05).unwrap();
    let dir = std::env::temp_dir().join("dsg_native_train_test");
    std::fs::create_dir_all(&dir).unwrap();
    let p = dir.join("zvc_tape.ckpt");
    checkpoint::save(&p, &t.state).unwrap();
    let mut resumed_zvc = NativeTrainer::with_state(meta.clone(), checkpoint::load(&p).unwrap())
        .unwrap()
        .with_tape(TapeStorage::Zvc);
    let mut resumed_dense =
        NativeTrainer::with_state(meta, checkpoint::load(&p).unwrap()).unwrap();
    let a = t.step(&x, &y, 0.5, 0.05).unwrap();
    let b = resumed_zvc.step(&x, &y, 0.5, 0.05).unwrap();
    let c = resumed_dense.step(&x, &y, 0.5, 0.05).unwrap();
    assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "zvc resume diverged");
    assert_eq!(a.loss.to_bits(), c.loss.to_bits(), "cross-tape resume diverged");
    assert_state_bits_eq(&t.state, &resumed_zvc.state, "zvc resume");
    assert_state_bits_eq(&t.state, &resumed_dense.state, "cross-tape resume");
}

#[test]
fn compound_kernels_multi_epoch_bit_parity_mlp() {
    // the compound kernels (input AND output sparsity) must reproduce
    // the PR 3 output-sparse-only kernels to the BIT over a real
    // multi-epoch run — losses, weights, velocities, BN running stats —
    // at gamma 0 (keep-all) and 0.5
    for &gamma in &[0.0f32, 0.5] {
        let meta = zoo::synth_meta(&smoke_spec()).unwrap();
        let mut cfg = RunConfig::preset_for_model("mlp");
        cfg.steps = 12;
        cfg.eval_every = 4;
        cfg.train_size = 64;
        cfg.test_size = 32;
        cfg.gamma = GammaSchedule::Constant(gamma);
        let data = datasets::fashion_like(cfg.train_size + cfg.test_size, cfg.seed);
        let (train, test) = data.split(1.0 / 3.0);
        let mut baseline = NativeTrainer::new(meta.clone(), 5)
            .unwrap()
            .with_kernels(SparseKernels::OutputSparse);
        let mut compound = NativeTrainer::new(meta, 5).unwrap(); // default = Compound
        let acc_a = baseline.train(&cfg, &train, &test).unwrap();
        let acc_b = compound.train(&cfg, &train, &test).unwrap();
        assert_eq!(acc_a.to_bits(), acc_b.to_bits(), "gamma {gamma}: eval acc");
        for (a, b) in baseline.history.steps.iter().zip(&compound.history.steps) {
            assert_eq!(
                a.loss.to_bits(),
                b.loss.to_bits(),
                "gamma {gamma} step {}: loss diverged",
                a.step
            );
            assert_eq!(a.densities, b.densities, "gamma {gamma} step {}", a.step);
        }
        assert_state_bits_eq(&baseline.state, &compound.state, &format!("gamma {gamma}"));
    }
}

#[test]
fn compound_kernels_bit_parity_on_conv_residual_topology() {
    // same claim through conv / residual / maxpool / gap backwards
    let meta = zoo::synth_meta(&tiny_conv_spec()).unwrap();
    let mut baseline = NativeTrainer::new(meta.clone(), 9)
        .unwrap()
        .with_kernels(SparseKernels::OutputSparse);
    let mut compound = NativeTrainer::new(meta.clone(), 9).unwrap();
    for step in 0u64..4 {
        let (x, y) = batch_for(&meta, 60 + step);
        let a = baseline.step(&x, &y, 0.5, 0.05).unwrap();
        let b = compound.step(&x, &y, 0.5, 0.05).unwrap();
        assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "step {step}");
    }
    assert_state_bits_eq(&baseline.state, &compound.state, "tinyconv compound");
}

#[test]
fn ops_counter_records_realized_reduction() {
    // two hidden layers so the SECOND one sees a genuinely sparse
    // input (layer 1's mask + relu zeros): there the compound kernels
    // must realize strictly fewer multiply-adds than the output-sparse
    // kernels, which in turn beat the dense baseline; at gamma 0 the
    // total sits at (or just under — relu'd-away gradients are skipped
    // and counted as skipped) the dense baseline, never above it
    let spec = ModelSpec::custom_mlp("ops_mlp", &[32, 200, 200], 4, 16);
    let meta = zoo::synth_meta(&spec).unwrap();
    let (x, y) = batch_for(&meta, 71);

    let mut dense_run = NativeTrainer::new(meta.clone(), 7).unwrap();
    dense_run.step(&x, &y, 0.0, 0.05).unwrap();
    let ops0 = dense_run.ops();
    assert!(ops0.total_dense() > 0);
    assert!(ops0.total_realized() <= ops0.total_dense());
    assert!(ops0.reduction() >= 1.0);

    // gamma 0.6 puts the mask density (~0.4) under the default 0.5
    // dispatch cutoff, so layer 2 engages the input-side gather
    let mut compound = NativeTrainer::new(meta.clone(), 7).unwrap();
    compound.step(&x, &y, 0.6, 0.05).unwrap();
    let mut baseline = NativeTrainer::new(meta, 7)
        .unwrap()
        .with_kernels(SparseKernels::OutputSparse);
    baseline.step(&x, &y, 0.6, 0.05).unwrap();
    let co = compound.ops();
    let bo = baseline.ops();
    assert_eq!(co.total_dense(), bo.total_dense(), "same dense baseline");
    assert!(
        co.total_realized() < bo.total_realized(),
        "compound realized {} not below output-sparse {}",
        co.total_realized(),
        bo.total_realized()
    );
    assert!(
        co.reduction() > bo.reduction() && bo.reduction() > 1.0,
        "reductions not ordered: compound {:.2}x vs output-sparse {:.2}x",
        co.reduction(),
        bo.reduction()
    );
    // per-layer records exist for both masked layers AND the classifier
    assert!(co.layers().len() >= 3, "expected per-layer ops records");
}

// ------------------------------------------------- crash-safe training

/// Fresh empty temp dir for a crash-recovery scenario.
fn crash_dir(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("dsg_crash_{name}"));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn crash_cfg() -> RunConfig {
    let mut cfg = RunConfig::preset_for_model("mlp");
    cfg.steps = 8;
    cfg.eval_every = 0;
    cfg.train_size = 64;
    cfg.test_size = 32;
    cfg.gamma = GammaSchedule::Constant(0.5);
    cfg
}

fn crash_trainer() -> NativeTrainer {
    let spec = ModelSpec::custom_mlp("crash_mlp", &[784, 16], 10, 16);
    let meta = zoo::synth_meta(&spec).unwrap();
    NativeTrainer::new(meta, 4).unwrap().with_tape(TapeStorage::Zvc)
}

/// The headline invariant of the recovery plane: kill a training run at
/// EVERY injectable fault site on its path, resume with `--resume
/// auto` semantics, and the final weights are bit-identical to an
/// uninterrupted run — faults move time, never bits.
#[test]
fn kill_at_every_fault_site_resume_parity() {
    let cfg = crash_cfg();
    let data = datasets::fashion_like(cfg.train_size + cfg.test_size, cfg.seed);
    let (train, test) = data.split(1.0 / 3.0);

    // baseline: uninterrupted, no checkpointing machinery at all
    let mut base = crash_trainer();
    base.train(&cfg, &train, &test).unwrap();

    // (site, kind, first failing hit): write faults die at the first
    // save; the tape fault dies mid-backward AFTER a checkpoint exists
    let scenarios: &[(&str, FaultKind, u64)] = &[
        ("ckpt.write", FaultKind::Io, 1),
        ("ckpt.write", FaultKind::Torn, 2),
        ("ckpt.fsync", FaultKind::Io, 1),
        ("ckpt.rename", FaultKind::Io, 1),
        ("tape.decompress", FaultKind::Io, 7),
    ];
    for &(site, kind, at) in scenarios {
        let what = format!("{site}:{kind:?}@{at}+");
        let dir = crash_dir(&format!("{}_{at}", site.replace('.', "_")));
        let ckpt = CheckpointDir::new(&dir).unwrap().with_keep(2);

        // the victim run: no save retries, so the first injected fault
        // on the save path is fatal (simulating a crash at that point)
        let opts = TrainOptions::checkpointed(ckpt.clone(), 2).with_save_retries(0);
        let plan = FaultPlan::one(site, kind, at, true);
        let mut victim = crash_trainer();
        let r = faults::with_plan(&plan, || victim.train_opts(&cfg, &train, &test, &opts));
        assert!(r.is_err(), "{what}: injected fault did not kill the run");

        // recovery: a fresh process-equivalent trainer, faults gone,
        // resuming from whatever valid checkpoint survived (possibly
        // none — dying at the first save means training from scratch)
        let mut resumed = crash_trainer();
        let opts = TrainOptions::checkpointed(ckpt, 2).with_resume(true);
        resumed.train_opts(&cfg, &train, &test, &opts).unwrap();
        assert_state_bits_eq(&base.state, &resumed.state, &what);
        assert_eq!(base.state.digest(), resumed.state.digest(), "{what}: digest");
    }
}

/// Resume without any faults: a run stopped cleanly at step 4 and
/// resumed to 8 matches a straight-through 8-step run bit for bit
/// (the batch iterator and schedules fast-forward deterministically).
#[test]
fn clean_mid_run_resume_is_bit_exact() {
    let cfg = crash_cfg();
    let data = datasets::fashion_like(cfg.train_size + cfg.test_size, cfg.seed);
    let (train, test) = data.split(1.0 / 3.0);

    let dir_a = crash_dir("clean_straight");
    let mut a = crash_trainer();
    let opts_a = TrainOptions::checkpointed(CheckpointDir::new(&dir_a).unwrap(), 3);
    a.train_opts(&cfg, &train, &test, &opts_a).unwrap();

    let dir_b = crash_dir("clean_resumed");
    let mut half = cfg.clone();
    half.steps = 4;
    let mut b1 = crash_trainer();
    let opts_b = TrainOptions::checkpointed(CheckpointDir::new(&dir_b).unwrap(), 3);
    b1.train_opts(&half, &train, &test, &opts_b).unwrap();
    // the digest is sensitive: half-trained and fully-trained differ
    assert_ne!(b1.state.digest(), a.state.digest());

    let mut b2 = crash_trainer();
    let opts_b = opts_b.with_resume(true);
    b2.train_opts(&cfg, &train, &test, &opts_b).unwrap();
    assert_state_bits_eq(&a.state, &b2.state, "clean resume");
    assert_eq!(a.state.digest(), b2.state.digest());
    // history covers only the replayed tail, not the first 4 steps
    assert_eq!(b2.history.steps.len(), 4);
}

/// `latest_valid` recovery order: a newer-but-corrupt checkpoint (torn
/// tail, flipped byte, or stray tmp) is skipped in favor of the newest
/// one that passes its CRCs.
#[test]
fn load_latest_valid_skips_torn_and_corrupt() {
    let dir = crash_dir("latest_valid");
    let ckpt = CheckpointDir::new(&dir).unwrap().with_keep(10);
    let mut t = crash_trainer();
    let meta = t.meta.clone();
    let (x, y) = batch_for(&meta, 81);
    t.step(&x, &y, 0.5, 0.05).unwrap();
    let good = t.state.clone();
    ckpt.save_step(&good, 2).unwrap();

    // a newer torn checkpoint (truncated mid-file), a newer garbage
    // one, and a stray tmp from an interrupted save
    let valid = std::fs::read(dir.join("step-0000000002.ckpt")).unwrap();
    std::fs::write(dir.join("step-0000000004.ckpt"), &valid[..valid.len() / 2]).unwrap();
    let mut flipped = valid.clone();
    let mid = flipped.len() / 2;
    flipped[mid] ^= 0x40;
    std::fs::write(dir.join("step-0000000006.ckpt"), &flipped).unwrap();
    std::fs::write(dir.join(".step-0000000008.ckpt.tmp"), &valid[..8]).unwrap();

    let (ms, steps, path) = CheckpointDir::new(&dir)
        .unwrap()
        .latest_valid()
        .unwrap()
        .expect("the valid checkpoint must be found");
    assert_eq!(steps, 2);
    assert!(path.ends_with("step-0000000002.ckpt"), "{path:?}");
    assert_state_bits_eq(&good, &ms, "latest_valid");
}

// ------------------------------------ data-parallel (sharded) training

/// Same model/seed/tape as [`crash_trainer`], but sharded.
fn par_trainer(shards: usize) -> ParallelTrainer {
    let spec = ModelSpec::custom_mlp("crash_mlp", &[784, 16], 10, 16);
    let meta = zoo::synth_meta(&spec).unwrap();
    ParallelTrainer::new(meta, 4, shards)
        .unwrap()
        .with_tape(TapeStorage::Zvc)
}

/// The fig10-style convergence claim: the SAME run at `--shards`
/// 1/2/4/8 produces bit-identical losses, densities, eval accuracy,
/// weights, BN stats, and digest — the shard count moves work, never
/// bits.  A different total thread budget must not move them either.
#[test]
fn shard_count_parity_is_bit_identical() {
    let cfg = crash_cfg();
    let data = datasets::fashion_like(cfg.train_size + cfg.test_size, cfg.seed);
    let (train, test) = data.split(1.0 / 3.0);

    let mut one = par_trainer(1);
    let acc1 = one.train(&cfg, &train, &test).unwrap();
    for shards in [2usize, 4, 8] {
        let mut t = par_trainer(shards);
        let acc = t.train(&cfg, &train, &test).unwrap();
        assert_eq!(acc.to_bits(), acc1.to_bits(), "{shards} shards: eval acc");
        assert_eq!(one.history.steps.len(), t.history.steps.len());
        for (a, b) in one.history.steps.iter().zip(&t.history.steps) {
            assert_eq!(
                a.loss.to_bits(),
                b.loss.to_bits(),
                "{shards} shards step {}: loss diverged",
                a.step
            );
            assert_eq!(a.densities, b.densities, "{shards} shards step {}", a.step);
        }
        assert_state_bits_eq(&one.state, &t.state, &format!("{shards} shards"));
        assert_eq!(one.state.digest(), t.state.digest(), "{shards} shards: digest");
        // the exchange actually went over the (in-process) wire, and the
        // sparse gradients compressed
        let w = t.wire_stats();
        assert!(w.grad_dense_bytes > 0 && w.frame_bytes > 0, "{shards} shards: no wire traffic");
        assert!(w.ratio() >= 1.0, "{shards} shards: ZVC expanded the gradients");
    }
    // uneven thread budget over 2 shards: same bits
    let mut odd = par_trainer(2).with_threads(5).unwrap();
    odd.train(&cfg, &train, &test).unwrap();
    assert_eq!(one.state.digest(), odd.state.digest(), "thread budget moved bits");
}

/// [`kill_at_every_fault_site_resume_parity`] extended to the
/// data-parallel sites: a persistent fault at `shard.step` or either
/// side of the all-reduce (including torn ZVC gradient frames) kills
/// the run once every shard exhausts its retries, and `--resume auto`
/// finishes to a digest bit-identical to an uninterrupted sharded run.
/// The torn cases double as the never-silently-summed check: a
/// truncated frame that slipped past the canonical-form decoder would
/// corrupt the weights and fail the digest assertion.
#[test]
fn kill_at_every_shard_fault_site_resume_parity() {
    let cfg = crash_cfg();
    let data = datasets::fashion_like(cfg.train_size + cfg.test_size, cfg.seed);
    let (train, test) = data.split(1.0 / 3.0);

    for shards in [2usize, 4] {
        let mut base = par_trainer(shards);
        base.train(&cfg, &train, &test).unwrap();

        // hit 17: the batch is 16 rows = 8 leaves, so each site fires 8
        // times per step — two full steps (and the step-2 checkpoint)
        // complete before the fault lands mid-step-3 and stays on
        let scenarios: &[(&str, FaultKind)] = &[
            ("shard.step", FaultKind::Io),
            ("allreduce.send", FaultKind::Io),
            ("allreduce.send", FaultKind::Torn),
            ("allreduce.recv", FaultKind::Io),
            ("allreduce.recv", FaultKind::Torn),
        ];
        for &(site, kind) in scenarios {
            let what = format!("{shards} shards {site}:{kind:?}@17+");
            let dir = crash_dir(&format!("{}_{kind:?}_s{shards}", site.replace('.', "_")));
            let ckpt = CheckpointDir::new(&dir).unwrap().with_keep(2);

            let opts = TrainOptions::checkpointed(ckpt.clone(), 2).with_save_retries(0);
            let plan = FaultPlan::one(site, kind, 17, true);
            let mut victim = par_trainer(shards);
            let r = faults::with_plan(&plan, || victim.train_opts(&cfg, &train, &test, &opts));
            assert!(r.is_err(), "{what}: persistent fault did not kill the run");

            let mut resumed = par_trainer(shards);
            let opts = TrainOptions::checkpointed(ckpt, 2).with_resume(true);
            resumed.train_opts(&cfg, &train, &test, &opts).unwrap();
            assert_state_bits_eq(&base.state, &resumed.state, &what);
            assert_eq!(base.state.digest(), resumed.state.digest(), "{what}: digest");
        }
    }
}

/// One-shot faults are absorbed in-run: the blamed shard recomputes the
/// same leaves on the same data, so the result is bit-identical to an
/// undisturbed run — including a torn gradient frame (rejected by the
/// canonical-form check, recomputed, never summed) and stalls (absorbed
/// in place, no retry).
#[test]
fn transient_shard_faults_are_absorbed_bit_exactly() {
    let cfg = crash_cfg();
    let data = datasets::fashion_like(cfg.train_size + cfg.test_size, cfg.seed);
    let (train, test) = data.split(1.0 / 3.0);

    let mut base = par_trainer(2);
    base.train(&cfg, &train, &test).unwrap();
    for (site, kind) in [
        ("shard.step", FaultKind::Io),
        ("shard.step", FaultKind::Stall),
        ("allreduce.send", FaultKind::Io),
        ("allreduce.send", FaultKind::Torn),
        ("allreduce.send", FaultKind::Stall),
        ("allreduce.recv", FaultKind::Io),
        ("allreduce.recv", FaultKind::Torn),
        ("allreduce.recv", FaultKind::Stall),
    ] {
        let what = format!("{site}:{kind:?}@3");
        let plan = FaultPlan::one(site, kind, 3, false);
        let mut t = par_trainer(2).with_max_retries(10);
        faults::with_plan(&plan, || t.train(&cfg, &train, &test)).unwrap();
        assert_state_bits_eq(&base.state, &t.state, &what);
        assert_eq!(base.state.digest(), t.state.digest(), "{what}: digest");
        assert!(t.shard_stats().iter().all(|s| s.alive), "{what}: a shard died");
        if kind != FaultKind::Stall {
            assert!(
                t.shard_stats().iter().any(|s| s.retries > 0),
                "{what}: fault absorbed without any blamed round"
            );
        }
    }
}

/// A shard stalled past the per-step deadline is treated as a
/// straggler: the coordinator times the round out, blames the owner,
/// and the retry recomputes the same leaves on the same data — time
/// moves, bits don't.
#[test]
fn straggler_past_deadline_is_retried_bit_exactly() {
    let cfg = crash_cfg();
    let data = datasets::fashion_like(cfg.train_size + cfg.test_size, cfg.seed);
    let (train, test) = data.split(1.0 / 3.0);

    let mut base = par_trainer(2);
    base.train(&cfg, &train, &test).unwrap();

    // stall (default 50 ms, see DSG_FAULT_STALL_MS) >> 10 ms deadline;
    // generous retry budget so a slow CI machine timing out a clean
    // round costs a recompute, never the run
    let plan = FaultPlan::one("shard.step", FaultKind::Stall, 3, false);
    let mut t = par_trainer(2)
        .with_deadline(Duration::from_millis(10))
        .with_max_retries(50);
    faults::with_plan(&plan, || t.train(&cfg, &train, &test)).unwrap();
    assert_state_bits_eq(&base.state, &t.state, "straggler retry");
    assert_eq!(base.state.digest(), t.state.digest());
    assert!(
        t.shard_stats().iter().any(|s| s.retries > 0),
        "the stalled shard was never blamed"
    );
    assert!(t.shard_stats().iter().all(|s| s.alive));
}

/// A shard that keeps dying is declared lost and its leaves re-shard
/// onto the survivors — deterministically: the leaf split and the
/// reduction tree never moved, so the digest matches an undisturbed
/// run bit for bit.
#[test]
fn lost_shard_reshards_deterministically() {
    let cfg = crash_cfg();
    let data = datasets::fashion_like(cfg.train_size + cfg.test_size, cfg.seed);
    let (train, test) = data.split(1.0 / 3.0);

    let mut base = par_trainer(2);
    base.train(&cfg, &train, &test).unwrap();

    // zero retry budget: the first blamed round kills the shard
    let plan = FaultPlan::one("shard.step", FaultKind::Io, 3, false);
    let mut t = par_trainer(2).with_max_retries(0);
    faults::with_plan(&plan, || t.train(&cfg, &train, &test)).unwrap();
    assert_state_bits_eq(&base.state, &t.state, "lost shard");
    assert_eq!(base.state.digest(), t.state.digest(), "re-shard moved bits");
    assert_eq!(
        t.shard_stats().iter().filter(|s| !s.alive).count(),
        1,
        "exactly one shard should be lost: {:?}",
        t.shard_stats()
    );
    assert!(t.reshards() >= 1, "no re-shard event recorded");
    // the survivor carried the whole rest of the run
    assert!(t.shard_stats().iter().any(|s| s.alive && s.leaves_done > 0));
}

#[test]
fn eval_forward_agrees_with_inference_engine() {
    // the training engine's eval forward (running-stat BN) and the
    // serving NativeModel (prefolded BN) are two implementations of the
    // same math; on a fresh state they must agree closely and pick the
    // same classes
    let meta = zoo::synth_meta(&smoke_spec()).unwrap();
    let mut state = ModelState::init(&meta, 6);
    dsg::native::project_host(&meta, &mut state).unwrap();
    let mut engine = TrainEngine::new(&meta, &state).unwrap();
    let nm = dsg::native::NativeModel::new(&meta, &state).unwrap();
    let (x, _) = batch_for(&meta, 31);
    let gamma = 0.6;
    let a = engine
        .forward_eval(&state, &x, meta.batch, gamma, Mode::Dsg)
        .unwrap();
    let xt = dsg::Tensor::new(&[meta.batch, meta.input_elems()], x.clone());
    // threads=1 routes both engines through the identical chunk kernels,
    // so the DRS selection is bit-identical and only the BN folding
    // (prefolded affine vs direct normalize) can differ
    let b = nm.forward_threaded(&xt, gamma, Mode::Dsg, 1).unwrap();
    assert_eq!(a.len(), b.logits.len());
    let c = meta.classes;
    for i in 0..meta.batch {
        let ra = &a[i * c..(i + 1) * c];
        let rb = &b.logits.data()[i * c..(i + 1) * c];
        for (va, vb) in ra.iter().zip(rb) {
            assert!((va - vb).abs() < 1e-3, "row {i}: {va} vs {vb}");
        }
    }
}
