//! Wire-serving integration: the socket front-end must be a transparent
//! transport — predictions served over TCP (and Unix sockets) are
//! bit-identical to in-process sharded serving, rejects and errors come
//! back as explicit frames, and a malformed client cannot take the
//! server down.

use dsg::serve::server::{drive_load, ClientEvent, Endpoint, WireServer};
use dsg::serve::wire::{read_frame, write_frame, Message};
use dsg::serve::{RejectReason, ShardReport, ShardedConfig, ShardedServer, SynthModel};
use std::io::Write;
use std::time::Duration;

const DIMS: &[usize] = &[64, 96, 80];
const CLASSES: usize = 10;
const BATCH: usize = 8;
const GAMMA: f32 = 0.7;

fn images(n: usize) -> Vec<Vec<f32>> {
    let m = SynthModel::new(1, DIMS, CLASSES, GAMMA);
    (0..n).map(|i| m.synth_image(500 + i as u64)).collect()
}

/// Server config for deterministic wire runs: a huge deadline means no
/// mid-stream flush can split a batch; the client's trailing `Flush`
/// ships the partial tail instead.
fn wire_cfg(shards: usize, workers: usize) -> ShardedConfig {
    ShardedConfig::new(shards, workers, BATCH, DIMS[0], CLASSES)
        .with_max_wait(Duration::from_secs(60))
}

fn model_forward(intra: usize) -> impl Fn(&[f32]) -> anyhow::Result<Vec<f32>> + Send + Sync {
    let model = SynthModel::new(1, DIMS, CLASSES, GAMMA).with_intra_threads(intra);
    move |xs: &[f32]| model.forward(xs, BATCH)
}

fn serve_over(
    endpoint: &Endpoint,
    cfg: ShardedConfig,
    imgs: &[Vec<f32>],
) -> (Vec<usize>, ShardReport) {
    let server = WireServer::bind(endpoint, cfg, model_forward(1)).unwrap();
    let addr = server.local_endpoint().clone();
    let handle = std::thread::spawn(move || server.run().unwrap());
    let run = drive_load(&addr, imgs, true).unwrap();
    let report = handle.join().unwrap();
    (run.predictions(), report)
}

#[test]
fn tcp_served_predictions_match_in_process() {
    let imgs = images(45);
    // ground truth: in-process sharded serve_all at 1x1
    let in_process =
        ShardedServer::serve_all(wire_cfg(1, 1), model_forward(1), imgs.clone()).unwrap();
    for (shards, workers) in [(1usize, 1usize), (2, 2), (4, 8)] {
        let (preds, report) = serve_over(
            &Endpoint::parse("127.0.0.1:0"),
            wire_cfg(shards, workers),
            &imgs,
        );
        assert_eq!(
            preds,
            in_process.predictions(),
            "socket serving diverged at {shards} shards x {workers} workers"
        );
        assert_eq!(report.served, 45);
        assert_eq!(report.failed, 0);
        assert_eq!(report.rejected, 0);
    }
}

#[cfg(unix)]
#[test]
fn unix_socket_serves_identically() {
    let imgs = images(21);
    let in_process =
        ShardedServer::serve_all(wire_cfg(1, 1), model_forward(1), imgs.clone()).unwrap();
    let path = std::env::temp_dir().join(format!("dsg_wire_test_{}.sock", std::process::id()));
    let ep = Endpoint::Unix(path.clone());
    let (preds, report) = serve_over(&ep, wire_cfg(2, 2), &imgs);
    assert_eq!(preds, in_process.predictions());
    assert_eq!(report.served, 21);
    assert!(!path.exists(), "server must remove its socket file on shutdown");
}

#[test]
fn overload_rejects_arrive_as_frames() {
    // Tiny queue cap + slow forward: part of the burst must come back
    // as Reject frames, and every admitted request must still answer.
    let cfg = ShardedConfig::new(1, 1, BATCH, DIMS[0], CLASSES)
        .with_queue_cap(1)
        .with_max_wait(Duration::from_millis(1));
    let model = SynthModel::new(1, DIMS, CLASSES, GAMMA);
    let server = WireServer::bind(&Endpoint::parse("127.0.0.1:0"), cfg, move |xs: &[f32]| {
        std::thread::sleep(Duration::from_millis(15));
        model.forward(xs, BATCH)
    })
    .unwrap();
    let addr = server.local_endpoint().clone();
    let handle = std::thread::spawn(move || server.run().unwrap());
    let imgs = images(120);
    let run = drive_load(&addr, &imgs, true).unwrap();
    let report = handle.join().unwrap();
    let served = run.served();
    let rejected = run.rejected();
    assert_eq!(served + rejected, 120, "every request needs a terminal frame");
    assert!(rejected > 0, "a 120-burst past a 1-block cap must reject over the wire");
    for e in &run.events {
        if let ClientEvent::Reject { reason, .. } = e {
            assert_eq!(*reason, RejectReason::Overloaded);
        }
    }
    assert_eq!(report.served, served);
    assert_eq!(report.rejected as usize, rejected);
}

#[test]
fn malformed_frame_kills_connection_not_server() {
    let server =
        WireServer::bind(&Endpoint::parse("127.0.0.1:0"), wire_cfg(1, 1), model_forward(1))
            .unwrap();
    let addr = server.local_endpoint().clone();
    let handle = std::thread::spawn(move || server.run().unwrap());
    let Endpoint::Tcp(tcp_addr) = addr.clone() else { panic!("expected tcp") };

    // connection 1: hostile length prefix, then a dead socket
    {
        let mut s = std::net::TcpStream::connect(&tcp_addr).unwrap();
        s.write_all(&[0xFF, 0xFF, 0xFF, 0xFF]).unwrap();
        // server drops this connection; give the handler a beat
        let mut r = s.try_clone().unwrap();
        r.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let got = read_frame(&mut r);
        assert!(
            matches!(&got, Ok(None)) || got.is_err(),
            "server must close a malformed connection, got {got:?}"
        );
    }

    // connection 2: a full serve still works afterwards
    let imgs = images(10);
    let run = drive_load(&addr, &imgs, true).unwrap();
    assert_eq!(run.served(), 10);
    let report = handle.join().unwrap();
    assert_eq!(report.served, 10);
}

#[test]
fn ping_pong_and_clean_shutdown() {
    let server =
        WireServer::bind(&Endpoint::parse("127.0.0.1:0"), wire_cfg(2, 2), model_forward(1))
            .unwrap();
    let Endpoint::Tcp(tcp_addr) = server.local_endpoint().clone() else { panic!("expected tcp") };
    let handle = std::thread::spawn(move || server.run().unwrap());

    let s = std::net::TcpStream::connect(&tcp_addr).unwrap();
    let mut w = s.try_clone().unwrap();
    let mut r = s;
    r.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    write_frame(&mut w, &Message::Ping { token: 42 }).unwrap();
    assert_eq!(read_frame(&mut r).unwrap(), Some(Message::Pong { token: 42 }));
    write_frame(&mut w, &Message::Shutdown).unwrap();
    drop((w, r));

    let report = handle.join().unwrap();
    assert_eq!(report.served, 0);
    assert_eq!(report.batches, 0);
}

#[test]
fn sequential_clients_each_get_the_in_process_answers() {
    // Two clients, one after the other, on fresh connections: each
    // client's 24 requests form 3 contiguous full blocks of their own
    // (drive_load waits for all answers before returning), so BOTH runs
    // must reproduce the in-process predictions exactly.
    let imgs = images(24);
    let in_process =
        ShardedServer::serve_all(wire_cfg(1, 1), model_forward(1), imgs.clone()).unwrap();
    let server =
        WireServer::bind(&Endpoint::parse("127.0.0.1:0"), wire_cfg(2, 4), model_forward(1))
            .unwrap();
    let addr = server.local_endpoint().clone();
    let handle = std::thread::spawn(move || server.run().unwrap());

    let run_a = drive_load(&addr, &imgs, false).unwrap();
    let run_b = drive_load(&addr, &imgs, false).unwrap();
    // stop the server with a third, control-only connection
    let run_stop = drive_load(&addr, &[], true).unwrap();
    assert!(run_stop.events.is_empty());
    let report = handle.join().unwrap();

    assert_eq!(run_a.predictions(), in_process.predictions(), "client A diverged");
    assert_eq!(run_b.predictions(), in_process.predictions(), "client B diverged");
    assert_eq!(report.served, 48);
}

#[test]
fn concurrent_clients_all_get_answers() {
    // Two clients interleaving: batch composition is timing-dependent
    // there (deliberately — streaming is), so assert COMPLETENESS (one
    // terminal frame per request, correctly correlated), not parity.
    let imgs = images(24);
    let server =
        WireServer::bind(&Endpoint::parse("127.0.0.1:0"), wire_cfg(2, 4), model_forward(1))
            .unwrap();
    let addr = server.local_endpoint().clone();
    let handle = std::thread::spawn(move || server.run().unwrap());

    let a_addr = addr.clone();
    let a_imgs = imgs.clone();
    let client_a = std::thread::spawn(move || drive_load(&a_addr, &a_imgs, false).unwrap());
    let b_imgs = imgs.clone();
    let b_addr = addr.clone();
    let client_b = std::thread::spawn(move || drive_load(&b_addr, &b_imgs, false).unwrap());
    let run_a = client_a.join().unwrap();
    let run_b = client_b.join().unwrap();
    let _ = drive_load(&addr, &[], true).unwrap();
    let report = handle.join().unwrap();

    assert_eq!(run_a.served(), 24);
    assert_eq!(run_b.served(), 24);
    assert_eq!(report.served, 48);
    assert_eq!(report.failed, 0);
}

#[test]
fn served_outcomes_are_not_double_collected() {
    // Wire-path requests reply through their hooks; the final report
    // must not ALSO collect them (that would double-count in benches).
    let imgs = images(9);
    let (_, report) = serve_over(&Endpoint::parse("127.0.0.1:0"), wire_cfg(1, 1), &imgs);
    assert_eq!(report.served, 9);
    assert!(
        report.outcomes.is_empty(),
        "replied outcomes must not be collected into the report"
    );
}
