//! Property-based tests over the coordinator-side substrates using the
//! in-repo mini framework (`dsg::testing`).  These encode the invariants
//! the paper's machinery depends on.

use dsg::drs::projection::ternary_r;
use dsg::drs::topk::{mask_density, select_mask, shared_threshold, SelectionStrategy};
use dsg::sparse;
use dsg::tensor::{ops, Tensor};
use dsg::testing::{forall, gen};
use dsg::util::Pcg32;
use dsg::zvc;

#[test]
fn prop_zvc_roundtrip() {
    forall(
        "zvc compress/decompress is identity",
        200,
        11,
        |rng| {
            let n = gen::usize_in(rng, 0, 700);
            let s = rng.uniform();
            gen::sparse_f32_vec(rng, n, s)
        },
        |xs| {
            let c = zvc::compress(xs);
            if zvc::decompress(&c) == *xs {
                Ok(())
            } else {
                Err("roundtrip mismatch".into())
            }
        },
    );
}

#[test]
fn prop_zvc_serialization_roundtrip() {
    forall(
        "zvc byte serde is identity",
        100,
        12,
        |rng| {
            let n = gen::usize_in(rng, 0, 300);
            gen::sparse_f32_vec(rng, n, 0.6)
        },
        |xs| {
            let c = zvc::compress(xs);
            match zvc::from_bytes(&zvc::to_bytes(&c)) {
                Some(c2) if c2 == c => Ok(()),
                _ => Err("serde mismatch".into()),
            }
        },
    );
}

#[test]
fn prop_zvc_nbytes_matches_analytic() {
    forall(
        "analytic zvc size == actual",
        100,
        13,
        |rng| {
            let n = gen::usize_in(rng, 1, 2000);
            let s = rng.uniform();
            gen::sparse_f32_vec(rng, n, s)
        },
        |xs| {
            let c = zvc::compress(xs);
            let sp = 1.0 - c.values.len() as f64 / xs.len() as f64;
            if zvc::zvc_bytes(xs.len(), sp) == c.nbytes() {
                Ok(())
            } else {
                Err("analytic size mismatch".into())
            }
        },
    );
}

#[test]
fn prop_masked_matmul_equals_mask_times_dense() {
    forall(
        "dsg_vmm == dense * mask",
        40,
        14,
        |rng| {
            let m = gen::usize_in(rng, 1, 12);
            let d = gen::usize_in(rng, 1, 40);
            let n = gen::usize_in(rng, 1, 16);
            let x = Tensor::new(&[m, d], gen::f32_vec(rng, m * d, 1.0));
            let w = Tensor::new(&[d, n], gen::f32_vec(rng, d * n, 1.0));
            let mask = Tensor::from_fn(&[m, n], |i| {
                if (i * 2654435761) % 3 == 0 {
                    1.0
                } else {
                    0.0
                }
            });
            (x, w, mask)
        },
        |(x, w, mask)| {
            let wt = ops::transpose(w);
            let got = sparse::dsg_vmm(x, &wt, mask);
            let dense = ops::matmul_naive(x, w);
            for i in 0..got.len() {
                let want = dense.data()[i] * mask.data()[i];
                if (got.data()[i] - want).abs() > 1e-3 {
                    return Err(format!("elem {i}: {} vs {want}", got.data()[i]));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_threshold_monotone_in_gamma() {
    forall(
        "higher gamma => higher threshold => sparser mask",
        50,
        15,
        |rng| {
            let b = gen::usize_in(rng, 1, 8);
            let w = gen::usize_in(rng, 4, 300);
            Tensor::new(&[b, w], gen::f32_vec(rng, b * w, 1.0))
        },
        |virt| {
            let mut rng = Pcg32::seeded(0);
            let mut last = f32::NEG_INFINITY;
            let mut last_density = f64::INFINITY;
            for g in [0.0f32, 0.25, 0.5, 0.75, 0.9] {
                let t = shared_threshold(virt, g);
                if t < last {
                    return Err(format!("threshold not monotone at gamma {g}"));
                }
                let m = select_mask(virt, g, SelectionStrategy::Drs, &mut rng);
                let d = mask_density(&m);
                if d > last_density + 1e-9 {
                    return Err(format!("density not monotone at gamma {g}"));
                }
                last = t;
                last_density = d;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_projection_preserves_inner_products_statistically() {
    // JLL (paper eq. 4): average relative inner-product error over pairs
    // is bounded for k chosen by the calibrated bound at eps = 0.5.
    forall(
        "projection preserves inner products",
        10,
        16,
        |rng| {
            let d = gen::usize_in(rng, 512, 2048);
            let k = dsg::costmodel::jll::projection_dim(0.5, 256, d);
            let r = ternary_r(rng, k, d, 3);
            let scale = (1.0 / d as f32).sqrt();
            let x = Tensor::new(&[1, d], gen::f32_vec(rng, d, scale));
            let w = Tensor::new(&[1, d], gen::f32_vec(rng, d, scale));
            (x, w, r)
        },
        |(x, w, r)| {
            let fx = dsg::drs::project_rows(x, r);
            let fw = dsg::drs::project_rows(w, r);
            let hi: f32 = x.data().iter().zip(w.data()).map(|(a, b)| a * b).sum();
            let lo: f32 = fx.data().iter().zip(fw.data()).map(|(a, b)| a * b).sum();
            // |x| ~ |w| ~ 1, so absolute error ~ eps-scale; allow 4 sigma
            if (hi - lo).abs() < 0.5 {
                Ok(())
            } else {
                Err(format!("inner product error {} too large", (hi - lo).abs()))
            }
        },
    );
}

#[test]
fn prop_ternary_index_matches_dense_projection() {
    forall(
        "index-form projection == dense matmul projection",
        30,
        17,
        |rng| {
            let d = gen::usize_in(rng, 2, 80);
            let k = gen::usize_in(rng, 1, 40);
            let r = ternary_r(rng, k, d, 3);
            let x = Tensor::new(&[3, d], gen::f32_vec(rng, 3 * d, 1.0));
            (x, r)
        },
        |(x, r)| {
            let got = dsg::drs::project_rows(x, r);
            let k = r.shape()[0] as f32;
            let mut want = ops::matmul_naive(x, &ops::transpose(r));
            for v in want.data_mut() {
                *v /= k.sqrt();
            }
            if got.allclose(&want, 1e-3, 1e-3) {
                Ok(())
            } else {
                Err("projection mismatch".into())
            }
        },
    );
}

#[test]
fn prop_im2col_row_count_and_padding() {
    forall(
        "im2col geometry",
        40,
        18,
        |rng| {
            let n = gen::usize_in(rng, 1, 3);
            let c = gen::usize_in(rng, 1, 4);
            let h = gen::usize_in(rng, 3, 12);
            let k = gen::usize_in(rng, 1, 3);
            let pad = gen::usize_in(rng, 0, 1);
            let x = Tensor::new(&[n, c, h, h], gen::f32_vec(rng, n * c * h * h, 1.0));
            (x, k, pad)
        },
        |(x, k, pad)| {
            let (rows, p, q) = ops::im2col(x, *k, 1, *pad);
            let n = x.shape()[0];
            let c = x.shape()[1];
            let h = x.shape()[2];
            let want_p = h + 2 * pad - k + 1;
            if p != want_p || q != want_p {
                return Err(format!("bad out dims {p}x{q}, want {want_p}"));
            }
            if rows.shape() != [n * p * q, c * k * k] {
                return Err(format!("bad rows shape {:?}", rows.shape()));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_random_selection_is_exactly_sized() {
    forall(
        "random strategy keeps exact count per sample",
        40,
        19,
        |rng| {
            let b = gen::usize_in(rng, 1, 6);
            let w = gen::usize_in(rng, 2, 120);
            let g = rng.uniform() * 0.95;
            (Tensor::new(&[b, w], gen::f32_vec(rng, b * w, 1.0)), g)
        },
        |(virt, g)| {
            let mut rng = Pcg32::seeded(7);
            let m = select_mask(virt, *g, SelectionStrategy::Random, &mut rng);
            let w = virt.shape()[1];
            let keep = w - ((g * w as f32).floor() as usize).min(w - 1);
            for b in 0..virt.shape()[0] {
                let got: f32 = m.data()[b * w..(b + 1) * w].iter().sum();
                if got != keep as f32 {
                    return Err(format!("sample {b}: kept {got}, want {keep}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_json_roundtrip_arbitrary_trees() {
    forall(
        "json write->parse is identity",
        60,
        20,
        |rng| {
            fn build(rng: &mut Pcg32, depth: usize) -> dsg::Json {
                use dsg::Json;
                let choice = if depth == 0 { rng.below(4) } else { rng.below(6) };
                match choice {
                    0 => Json::Null,
                    1 => Json::Bool(rng.uniform() < 0.5),
                    2 => Json::Num((rng.normal() * 100.0).round() as f64),
                    3 => Json::Str(format!("s{}\n\"{}", rng.below(100), rng.below(10))),
                    4 => Json::Arr((0..rng.below(4)).map(|_| build(rng, depth - 1)).collect()),
                    _ => {
                        let mut m = std::collections::BTreeMap::new();
                        for i in 0..rng.below(4) {
                            m.insert(format!("k{i}"), build(rng, depth - 1));
                        }
                        Json::Obj(m)
                    }
                }
            }
            build(rng, 3)
        },
        |j| {
            let txt = j.to_string();
            match dsg::Json::parse(&txt) {
                Ok(j2) if j2 == *j => Ok(()),
                Ok(_) => Err(format!("roundtrip changed value: {txt}")),
                Err(e) => Err(format!("reparse failed: {e} on {txt}")),
            }
        },
    );
}

#[test]
fn prop_checkpoint_roundtrip_arbitrary_states() {
    use dsg::coordinator::{checkpoint, ModelState};
    use dsg::runtime::HostTensor;
    let dir = std::env::temp_dir().join("dsg_prop_ckpt");
    std::fs::create_dir_all(&dir).unwrap();
    forall(
        "checkpoint save/load is identity",
        25,
        21,
        |rng| {
            let mk = |rng: &mut Pcg32| {
                let n = gen::usize_in(rng, 1, 5);
                let m = gen::usize_in(rng, 1, 7);
                HostTensor::f32(&[n, m], gen::f32_vec(rng, n * m, 1.0))
            };
            ModelState {
                state: (0..gen::usize_in(rng, 1, 4)).map(|_| mk(rng)).collect(),
                wps: (0..gen::usize_in(rng, 0, 2)).map(|_| mk(rng)).collect(),
                rs: (0..gen::usize_in(rng, 0, 2)).map(|_| mk(rng)).collect(),
            }
        },
        |ms| {
            let p = dir.join("prop.ckpt");
            checkpoint::save(&p, ms).map_err(|e| e.to_string())?;
            let ms2 = checkpoint::load(&p).map_err(|e| e.to_string())?;
            if ms.state == ms2.state && ms.wps == ms2.wps && ms.rs == ms2.rs {
                Ok(())
            } else {
                Err("state mismatch".into())
            }
        },
    );
}
