//! Fig 8(b) + Fig 12: accuracy vs training time — large-sparse DSG
//! models against smaller-dense models with equivalent effective MACs.
//!
//! Expected: the equivalent smaller-dense nets train faster but lose
//! more accuracy than DSG at the same effective MAC budget.

use dsg::runtime::Runtime;

fn run(rt: &Runtime, label: &str, variant: &str, gamma: f32, steps: usize) -> anyhow::Result<(f32, f64)> {
    let (acc, t) = dsg::benchutil::train_at(rt, variant, gamma, steps, 7)?;
    let secs = t.history.total_secs();
    println!(
        "{:<24} gamma {:>4}  acc {:.3}  train-time {:>7.1}s  ({} steps)",
        label, gamma, acc, secs, steps
    );
    Ok((acc, secs))
}

fn main() -> anyhow::Result<()> {
    dsg::benchutil::header(
        "Fig 8(b) / Fig 12",
        "accuracy vs training time: large-sparse vs equivalent smaller-dense",
        "smaller-dense saves time but loses accuracy vs DSG at equal MACs",
    );
    let rt = Runtime::cpu()?;
    let steps = dsg::benchutil::bench_steps();
    let all = std::env::args().any(|a| a == "--all");

    println!("\nVGG8 family (w=32 base; dense-equivalents w=23 (~50%), w=14 (~80%)):");
    let (acc_dense, _) = run(&rt, "vgg8 dense", "vgg8_dense", 0.0, steps)?;
    let (acc_dsg50, _) = run(&rt, "vgg8 DSG", "vgg8", 0.5, steps)?;
    let (acc_d23, _) = run(&rt, "vgg8_d23 small-dense", "vgg8_d23", 0.0, steps)?;
    let (acc_dsg80, _) = run(&rt, "vgg8 DSG", "vgg8", 0.8, steps)?;
    let (acc_d14, _) = run(&rt, "vgg8_d14 small-dense", "vgg8_d14", 0.0, steps)?;
    println!(
        "\nat ~50% MACs: DSG {acc_dsg50:.3} vs small-dense {acc_d23:.3} (DSG should win; dense ref {acc_dense:.3})"
    );
    println!(
        "at ~20% MACs: DSG {acc_dsg80:.3} vs small-dense {acc_d14:.3}"
    );

    if all {
        println!("\nResNet8 family (Fig 12; w=16 base; equivalents w=11, w=7):");
        run(&rt, "resnet8 dense", "resnet8_dense", 0.0, steps)?;
        run(&rt, "resnet8 DSG", "resnet8", 0.5, steps)?;
        run(&rt, "resnet8_d11 small-dense", "resnet8_d11", 0.0, steps)?;
        run(&rt, "resnet8 DSG", "resnet8", 0.8, steps)?;
        run(&rt, "resnet8_d7 small-dense", "resnet8_d7", 0.0, steps)?;
    } else {
        println!("\n(run with --all for the ResNet8 / Fig 12 extension)");
    }
    Ok(())
}
