//! Fig 5(c): graph selection strategy — DRS vs oracle top-k vs random
//! selection, accuracy under increasing sparsity on vgg8s.
//!
//! Expected: DRS ~= oracle >> random at high sparsity.

use dsg::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    dsg::benchutil::header(
        "Fig 5(c)",
        "selection strategy: DRS vs oracle vs random",
        "DRS ~ oracle, both >> random under high sparsity",
    );
    let rt = Runtime::cpu()?;
    let steps = dsg::benchutil::bench_steps();
    let gammas = [0.0f32, 0.5, 0.7, 0.9];
    let mut last: Vec<(String, f32)> = Vec::new();
    for (label, variant) in [
        ("drs", "vgg8s"),
        ("oracle", "vgg8s_oracle"),
        ("random", "vgg8s_random"),
    ] {
        let mut series = Vec::new();
        for &g in &gammas {
            let (acc, _) = dsg::benchutil::train_at(&rt, variant, g, steps, 7)?;
            series.push((g, acc));
        }
        dsg::benchutil::print_series(label, &series);
        last.push((label.to_string(), series.last().unwrap().1));
    }
    let drs = last[0].1;
    let oracle = last[1].1;
    let random = last[2].1;
    println!(
        "\n@90%: drs {drs:.3} vs oracle {oracle:.3} (gap {:.3}); random {random:.3} (deficit {:.3})",
        (oracle - drs).abs(),
        drs - random
    );
    Ok(())
}
