//! Table 1: dimension-reduction search dimensions + operation counts for
//! the five VGG8 layers at eps in {0.3, 0.5, 0.7, 0.9}, plus the Appendix
//! B average reduction factors.

use dsg::costmodel::jll;
use dsg::sparse::engine::VGG8_LAYERS;

fn main() {
    dsg::benchutil::header(
        "Table 1",
        "DRS reduced dimension and MMACs per VGG8 layer vs eps",
        "dims 539/232/148/119 (nK=128) ... ops 67.37/29/18.5/14.88 MMACs; BL 144",
    );
    let epss = [0.3, 0.5, 0.7, 0.9];
    println!(
        "{:<24} {:>6} {:>6} {:>6} {:>6} {:>6} | {:>8} {:>8} {:>8} {:>8} {:>8}",
        "layer (nPQ,nCRS,nK)", "BL", "0.3", "0.5", "0.7", "0.9", "BL-MM", "0.3", "0.5", "0.7", "0.9"
    );
    let mut red = [0.0f64; 4];
    for l in VGG8_LAYERS {
        let dims: Vec<usize> =
            epss.iter().map(|&e| jll::projection_dim(e, l.n_k, l.n_crs)).collect();
        let ops: Vec<f64> =
            dims.iter().map(|&k| jll::search_mmacs(l.n_pq, k, l.n_k)).collect();
        println!(
            "{:<24} {:>6} {:>6} {:>6} {:>6} {:>6} | {:>8.2} {:>8.2} {:>8.2} {:>8.2} {:>8.2}",
            format!("{} ({},{},{})", l.name, l.n_pq, l.n_crs, l.n_k),
            l.n_crs,
            dims[0],
            dims[1],
            dims[2],
            dims[3],
            jll::baseline_mmacs(l.n_pq, l.n_crs, l.n_k),
            ops[0],
            ops[1],
            ops[2],
            ops[3]
        );
        for (i, &k) in dims.iter().enumerate() {
            red[i] += l.n_crs as f64 / k as f64;
        }
    }
    println!("\naverage dimension reduction (paper: 3.6x / 8.5x / 13.3x / 16.5x):");
    for (i, &e) in epss.iter().enumerate() {
        println!("  eps {:.1}: {:.1}x", e, red[i] / VGG8_LAYERS.len() as f64);
    }
}
