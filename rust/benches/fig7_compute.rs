//! Fig 7: computational cost (GMACs) for training and inference at
//! 50/80/90% sparsity, including the DRS search overhead.

use dsg::costmodel::{self, shapes::fig6_nets};

fn main() {
    dsg::benchutil::header(
        "Fig 7",
        "MAC counts for training (fwd+bwd) and inference",
        "train 1.4x/1.7x/2.2x; infer 1.5x/2.8x/3.9x; DRS <6.5% train, <19.5% infer",
    );
    for &gamma in &[0.5f64, 0.8, 0.9] {
        println!("\n--- sparsity {:.0}% (eps 0.5) ---", gamma * 100.0);
        println!(
            "{:<10} {:>10} {:>10} {:>8} {:>10} {:>10} {:>8} {:>9} {:>9}",
            "model", "tr-dense", "tr-dsg", "train-x", "inf-dense", "inf-dsg", "infer-x",
            "drs%tr", "drs%inf"
        );
        let (mut at, mut ai) = (0.0, 0.0);
        let nets = fig6_nets();
        for net in &nets {
            let m = costmodel::macs(net, gamma, 0.5);
            at += m.train_reduction();
            ai += m.infer_reduction();
            println!(
                "{:<10} {:>10.1} {:>10.1} {:>7.2}x {:>10.1} {:>10.1} {:>7.2}x {:>8.1}% {:>8.1}%",
                net.name,
                costmodel::gmacs(m.train_dense()),
                costmodel::gmacs(m.train_dsg()),
                m.train_reduction(),
                costmodel::gmacs(m.fwd_dense),
                costmodel::gmacs(m.fwd_dsg),
                m.infer_reduction(),
                100.0 * m.search_frac_train(),
                100.0 * m.search_frac_infer()
            );
        }
        println!(
            "average: train {:.2}x, inference {:.2}x",
            at / nets.len() as f64,
            ai / nets.len() as f64
        );
    }
}
