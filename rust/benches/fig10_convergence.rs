//! Fig 10: convergence analysis.
//! (a/b) DSG training curves vs the vanilla dense model — DSG must not
//!       slow convergence;
//! (c)   distribution of the pairwise difference between high-dim and
//!       low-dim (projected) inner products.

use dsg::drs::projection::ternary_r;
use dsg::drs::project_rows;
use dsg::runtime::Runtime;
use dsg::tensor::Tensor;
use dsg::util::Pcg32;

fn main() -> anyhow::Result<()> {
    dsg::benchutil::header(
        "Fig 10",
        "convergence: DSG vs dense curves + inner-product fidelity",
        "DSG convergence ~= vanilla; inner-product differences centered on 0",
    );
    let rt = Runtime::cpu()?;
    let steps = dsg::benchutil::bench_steps().max(100);

    // (a) loss curves dense vs DSG on mlp
    println!("\n(a) mlp loss curves ({steps} steps):");
    let (_, t_dense) = dsg::benchutil::train_at(&rt, "mlp_dense", 0.0, steps, 7)?;
    let (_, t_dsg) = dsg::benchutil::train_at(&rt, "mlp", 0.6, steps, 7)?;
    println!("{:>6} {:>12} {:>12}", "step", "dense", "dsg@60%");
    for i in (0..steps).step_by((steps / 10).max(1)) {
        let end = (i + 10).min(steps);
        let d: f32 = t_dense.history.steps[i..end].iter().map(|s| s.loss).sum::<f32>()
            / (end - i) as f32;
        let g: f32 = t_dsg.history.steps[i..end].iter().map(|s| s.loss).sum::<f32>()
            / (end - i) as f32;
        println!("{:>6} {:>12.4} {:>12.4}", i, d, g);
    }
    let d_final = t_dense.history.smoothed_loss(20).unwrap();
    let g_final = t_dsg.history.smoothed_loss(20).unwrap();
    println!("final smoothed loss: dense {d_final:.4} vs dsg {g_final:.4}");

    // (c) inner-product difference histogram (CONV5-like shape, Table 1)
    println!("\n(c) inner-product difference, d=2304 k=299 (eps 0.5, nK=512):");
    let mut rng = Pcg32::seeded(5);
    let (d, k, n) = (2304usize, 299usize, 4000usize);
    let r = ternary_r(&mut rng, k, d, 3);
    let scale = (1.0 / d as f32).sqrt();
    let mut diffs = Vec::with_capacity(n);
    for _ in 0..n {
        let x = Tensor::new(&[1, d], rng.normal_vec(d, scale));
        let w = Tensor::new(&[1, d], rng.normal_vec(d, scale));
        let hi: f32 = x.data().iter().zip(w.data()).map(|(a, b)| a * b).sum();
        let fx = project_rows(&x, &r);
        let fw = project_rows(&w, &r);
        let lo: f32 = fx.data().iter().zip(fw.data()).map(|(a, b)| a * b).sum();
        diffs.push((hi - lo) as f64);
    }
    let s = dsg::metrics::summarize(&diffs);
    println!("  mean {:+.4}  std {:.4}  min {:+.4}  max {:+.4}", s.mean, s.std, s.min, s.max);
    // histogram
    let bins = 13;
    let lo = -0.2;
    let hi = 0.2;
    let mut counts = vec![0usize; bins];
    for &d in &diffs {
        let b = (((d - lo) / (hi - lo) * bins as f64) as isize).clamp(0, bins as isize - 1);
        counts[b as usize] += 1;
    }
    for (i, c) in counts.iter().enumerate() {
        let center = lo + (i as f64 + 0.5) * (hi - lo) / bins as f64;
        println!("  {:+.3} {}", center, "#".repeat(c * 60 / n.max(1)));
    }
    println!("(distribution should be tightly centered on zero — eq. 4)");
    Ok(())
}
