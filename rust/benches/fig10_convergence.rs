//! Fig 10: convergence analysis.
//! (a/b) DSG training curves vs the vanilla dense model — DSG must not
//!       slow convergence;
//! (c)   distribution of the pairwise difference between high-dim and
//!       low-dim (projected) inner products.

use dsg::config::{GammaSchedule, RunConfig};
use dsg::coordinator::NativeTrainer;
use dsg::drs::projection::ternary_r;
use dsg::drs::project_rows;
use dsg::metrics::History;
use dsg::runtime::Runtime;
use dsg::tensor::Tensor;
use dsg::util::Pcg32;

/// Train one mlp variant on the NATIVE engine (no artifacts) at a
/// constant gamma; returns the step history.
fn native_curve(variant: &str, gamma: f32, steps: usize, seed: u64) -> anyhow::Result<History> {
    let meta = dsg::native::zoo::synth_meta(&dsg::native::zoo::spec_for(variant)?)?;
    let mut cfg = RunConfig::preset_for_model("mlp");
    cfg.steps = steps;
    cfg.eval_every = 0;
    cfg.seed = seed;
    cfg.train_size = 1024;
    cfg.test_size = 128;
    cfg.gamma = GammaSchedule::Constant(gamma);
    let (train, test) = dsg::benchutil::data_for(&cfg);
    let mut t = NativeTrainer::new(meta, seed)?;
    t.train(&cfg, &train, &test)?;
    Ok(t.history)
}

fn print_curves(label: &str, steps: usize, dense: &History, dsg: &History) {
    println!("{:>6} {:>12} {:>12}", "step", "dense", label);
    for i in (0..steps).step_by((steps / 10).max(1)) {
        let end = (i + 10).min(steps);
        let d: f32 =
            dense.steps[i..end].iter().map(|s| s.loss).sum::<f32>() / (end - i) as f32;
        let g: f32 = dsg.steps[i..end].iter().map(|s| s.loss).sum::<f32>() / (end - i) as f32;
        println!("{:>6} {:>12.4} {:>12.4}", i, d, g);
    }
    let d_final = dense.smoothed_loss(20).unwrap();
    let g_final = dsg.smoothed_loss(20).unwrap();
    println!("final smoothed loss: dense {d_final:.4} vs dsg {g_final:.4}");
}

fn main() -> anyhow::Result<()> {
    dsg::benchutil::header(
        "Fig 10",
        "convergence: DSG vs dense curves + inner-product fidelity",
        "DSG convergence ~= vanilla; inner-product differences centered on 0",
    );
    let steps = dsg::benchutil::bench_steps().max(100);

    // (a) loss curves dense vs DSG on mlp — NATIVE engine, runs with no
    // artifacts and no PJRT (the host-side Algorithm 1)
    println!("\n(a) mlp loss curves, native engine ({steps} steps):");
    let h_dense = native_curve("mlp_dense", 0.0, steps, 7)?;
    let h_dsg = native_curve("mlp", 0.6, steps, 7)?;
    print_curves("dsg@60%", steps, &h_dense, &h_dsg);
    let dens = h_dsg.mean_densities(20);
    if !dens.is_empty() {
        let joined: Vec<String> = dens.iter().map(|d| format!("{d:.3}")).collect();
        println!("mean dsg densities (last 20 steps): [{}]", joined.join(", "));
    }

    // (b) the same curves through the HLO artifacts, when available
    match Runtime::cpu() {
        Err(e) => println!("\n(b) HLO curves skipped: {e}"),
        Ok(rt) => {
            println!("\n(b) mlp loss curves, HLO artifacts ({steps} steps):");
            let (_, t_dense) = dsg::benchutil::train_at(&rt, "mlp_dense", 0.0, steps, 7)?;
            let (_, t_dsg) = dsg::benchutil::train_at(&rt, "mlp", 0.6, steps, 7)?;
            print_curves("dsg@60%", steps, &t_dense.history, &t_dsg.history);
        }
    }

    // (c) inner-product difference histogram (CONV5-like shape, Table 1)
    println!("\n(c) inner-product difference, d=2304 k=299 (eps 0.5, nK=512):");
    let mut rng = Pcg32::seeded(5);
    let (d, k, n) = (2304usize, 299usize, 4000usize);
    let r = ternary_r(&mut rng, k, d, 3);
    let scale = (1.0 / d as f32).sqrt();
    let mut diffs = Vec::with_capacity(n);
    for _ in 0..n {
        let x = Tensor::new(&[1, d], rng.normal_vec(d, scale));
        let w = Tensor::new(&[1, d], rng.normal_vec(d, scale));
        let hi: f32 = x.data().iter().zip(w.data()).map(|(a, b)| a * b).sum();
        let fx = project_rows(&x, &r);
        let fw = project_rows(&w, &r);
        let lo: f32 = fx.data().iter().zip(fw.data()).map(|(a, b)| a * b).sum();
        diffs.push((hi - lo) as f64);
    }
    let s = dsg::metrics::summarize(&diffs);
    println!("  mean {:+.4}  std {:.4}  min {:+.4}  max {:+.4}", s.mean, s.std, s.min, s.max);
    // histogram
    let bins = 13;
    let lo = -0.2;
    let hi = 0.2;
    let mut counts = vec![0usize; bins];
    for &d in &diffs {
        let b = (((d - lo) / (hi - lo) * bins as f64) as isize).clamp(0, bins as isize - 1);
        counts[b as usize] += 1;
    }
    for (i, c) in counts.iter().enumerate() {
        let center = lo + (i as f64 + 0.5) * (hi - lo) / bins as f64;
        println!("  {:+.3} {}", center, "#".repeat(c * 60 / n.max(1)));
    }
    println!("(distribution should be tightly centered on zero — eq. 4)");
    Ok(())
}
