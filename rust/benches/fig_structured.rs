//! Structured (constant fan-in) vs unstructured DRS selection — the
//! Lasby-style extension of Fig 5: does a per-row top-k mask in the
//! packed `FixedK` layout match the paper's shared-threshold selection
//! at matched gamma, and what do the packed-gather kernels buy over the
//! CSR kernels on the SAME selection?
//!
//! Two sections:
//!
//! 1. ACCURACY — native training of the same MLP at the same gamma
//!    under `--selection unstructured | structured | structured:blocked`
//!    (identical init, identical batches; only the mask-selection rule
//!    differs).  Final eval accuracy and late-training loss go into the
//!    JSON.
//! 2. KERNELS — one structured selection expressed packed (`FixedK`)
//!    and as explicit CSR ([`RowMask::to_csr`]), timed through the
//!    forward / backward-dX / gradW parallel engines.  Outputs are
//!    asserted bit-identical first (layout moves loads, never bits), so
//!    the timing delta is pure layout.
//!
//! Writes `BENCH_structured.json` (override with `DSG_BENCH_OUT`).
//! `DSG_STRUCTURED_SMOKE=1` shrinks both sections for CI.

use dsg::config::{GammaSchedule, RunConfig};
use dsg::coordinator::NativeTrainer;
use dsg::datasets;
use dsg::drs::{topk, SelectionMode};
use dsg::native::zoo::{self, ModelSpec};
use dsg::sparse::parallel;
use dsg::tensor::{ops, Tensor};
use dsg::util::json::{obj, Json};
use dsg::util::Pcg32;
use std::time::Instant;

fn randn(rng: &mut Pcg32, shape: &[usize]) -> Tensor {
    let n: usize = shape.iter().product();
    Tensor::new(shape, rng.normal_vec(n, 1.0))
}

fn accuracy_spec(smoke: bool) -> ModelSpec {
    if smoke {
        ModelSpec::custom_mlp("structured_smoke", &[64, 48], 6, 16)
    } else {
        ModelSpec::custom_mlp("structured_mlp", &[256, 200, 200], 10, 64)
    }
}

/// Train the spec'd MLP under one selection mode; returns (eval acc,
/// mean loss over the last 5 steps).
fn train_mode(spec: &ModelSpec, sel: SelectionMode, gamma: f32, steps: usize) -> anyhow::Result<(f32, f32)> {
    let meta = zoo::synth_meta(spec)?;
    let mut cfg = RunConfig::preset_for_model("mlp");
    cfg.model = meta.name.clone();
    cfg.steps = steps;
    cfg.eval_every = 0;
    cfg.train_size = (meta.batch * steps).min(2048);
    cfg.test_size = 256.min(cfg.train_size / 2).max(32);
    cfg.gamma = GammaSchedule::Constant(gamma);
    let data = datasets::fashion_like(cfg.train_size + cfg.test_size, cfg.seed);
    let split = cfg.test_size as f64 / (cfg.train_size + cfg.test_size) as f64;
    let (train, test) = data.split(split);
    let mut t = NativeTrainer::new(meta, cfg.seed)?.with_selection(sel);
    let acc = t.train(&cfg, &train, &test)?;
    let tail = t.history.steps.len().saturating_sub(5);
    let late = &t.history.steps[tail..];
    let loss = late.iter().map(|s| s.loss).sum::<f32>() / late.len().max(1) as f32;
    Ok((acc, loss))
}

/// Median wall time of `f` over `reps` runs (first run discarded as
/// warmup when reps allows).
fn time_median(reps: usize, mut f: impl FnMut()) -> f64 {
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    samples.sort_by(|a, b| a.total_cmp(b));
    samples[samples.len() / 2]
}

fn main() -> anyhow::Result<()> {
    dsg::benchutil::header(
        "Structured DRS",
        "constant fan-in selection vs the paper's shared threshold, packed-gather vs CSR kernels",
        "Lasby et al.: constant fan-in matches unstructured accuracy; regularity pays in kernels",
    );
    let smoke = std::env::var("DSG_STRUCTURED_SMOKE").is_ok();
    let gamma = 0.5f32;
    let steps = if smoke { 12 } else { 150 };

    // ---------------- accuracy at matched gamma ----------------
    let spec = accuracy_spec(smoke);
    println!(
        "\n=== accuracy: {} at gamma {gamma}, {steps} steps/mode{} ===",
        spec.name,
        if smoke { " (smoke)" } else { "" }
    );
    let modes = [
        SelectionMode::Unstructured,
        SelectionMode::Structured { blocked: false },
        SelectionMode::Structured { blocked: true },
    ];
    let mut mode_objs = Vec::new();
    let mut accs = Vec::new();
    println!("{:>20} {:>8} {:>12}", "selection", "acc", "late-loss");
    for sel in modes {
        let (acc, loss) = train_mode(&spec, sel, gamma, steps)?;
        assert!(loss.is_finite(), "{}: loss diverged", sel.label());
        println!("{:>20} {:>8.3} {:>12.4}", sel.label(), acc, loss);
        accs.push(acc);
        mode_objs.push(obj(vec![
            ("selection", Json::Str(sel.label().to_string())),
            ("acc", Json::Num(acc as f64)),
            ("late_loss", Json::Num(loss as f64)),
        ]));
    }
    if !smoke {
        let chance = 1.0 / 10.0f32;
        for (sel, &acc) in modes.iter().zip(&accs) {
            assert!(
                acc > chance + 0.1,
                "{}: accuracy {acc:.3} barely above chance",
                sel.label()
            );
        }
        // the Lasby claim at this scale: structured tracks unstructured
        assert!(
            (accs[1] - accs[0]).abs() < 0.15,
            "structured acc {:.3} far from unstructured {:.3}",
            accs[1],
            accs[0]
        );
    }

    // ---------------- kernel time: packed vs CSR ----------------
    let (m, d, n) = if smoke { (32, 96, 64) } else { (256, 512, 384) };
    let kgamma = 0.75f32;
    let reps = if smoke { 5 } else { 41 };
    let threads = parallel::n_threads();
    let mut rng = Pcg32::seeded(77);
    let mut xv = rng.normal_vec(m * d, 1.0);
    for (i, v) in xv.iter_mut().enumerate() {
        if i % 3 == 0 {
            *v = 0.0; // relu-style input zeros for the compound path
        }
    }
    let x = Tensor::new(&[m, d], xv);
    let w = randn(&mut rng, &[d, n]);
    let wt = ops::transpose(&w);
    let dy = randn(&mut rng, &[m, n]);
    let virt = randn(&mut rng, &[m, n]);
    let packed = topk::select_structured(&virt, kgamma, false);
    let k = packed.fixed_k().expect("structured selection is packed");
    let csr = packed.to_csr();
    // parity first: the timing below compares layouts of the SAME math
    let want = parallel::dsg_vmm_rowmask_parallel_with(&x, &wt, &csr, threads);
    assert_eq!(want, parallel::dsg_vmm_rowmask_parallel_with(&x, &wt, &packed, threads));
    let mut dx_csr = vec![0.0f32; m * d];
    let mut dx_packed = vec![0.0f32; m * d];
    parallel::dsg_vmm_rowmask_backward_parallel_into(
        dy.data(), m, d, wt.data(), n, &csr, threads, &mut dx_csr,
    );
    parallel::dsg_vmm_rowmask_backward_parallel_into(
        dy.data(), m, d, wt.data(), n, &packed, threads, &mut dx_packed,
    );
    assert_eq!(dx_csr, dx_packed, "backward parity");
    let mut gw_csr = vec![0.0f32; n * d];
    let mut gw_packed = vec![0.0f32; n * d];
    parallel::dsg_vmm_rowmask_gradw_parallel_into(
        x.data(), dy.data(), m, d, n, &csr, threads, &mut gw_csr,
    );
    parallel::dsg_vmm_rowmask_gradw_parallel_into(
        x.data(), dy.data(), m, d, n, &packed, threads, &mut gw_packed,
    );
    assert_eq!(gw_csr, gw_packed, "gradW parity");

    println!(
        "\n=== kernels: ({m} x {d}) @ ({d} x {n}), gamma {kgamma} -> k = {k}, {threads} threads, {reps} reps ==="
    );
    println!("{:>12} {:>12} {:>12} {:>8}", "kernel", "csr", "packed", "ratio");
    let mut kernel_objs = Vec::new();
    let mut fwd_ratio = 0.0f64;
    for (name, csr_s, packed_s) in [
        (
            "forward",
            time_median(reps, || {
                parallel::dsg_vmm_rowmask_parallel_with(&x, &wt, &csr, threads);
            }),
            time_median(reps, || {
                parallel::dsg_vmm_rowmask_parallel_with(&x, &wt, &packed, threads);
            }),
        ),
        (
            "backward_dx",
            time_median(reps, || {
                parallel::dsg_vmm_rowmask_backward_parallel_into(
                    dy.data(), m, d, wt.data(), n, &csr, threads, &mut dx_csr,
                );
            }),
            time_median(reps, || {
                parallel::dsg_vmm_rowmask_backward_parallel_into(
                    dy.data(), m, d, wt.data(), n, &packed, threads, &mut dx_packed,
                );
            }),
        ),
        (
            "gradw",
            time_median(reps, || {
                parallel::dsg_vmm_rowmask_gradw_parallel_into(
                    x.data(), dy.data(), m, d, n, &csr, threads, &mut gw_csr,
                );
            }),
            time_median(reps, || {
                parallel::dsg_vmm_rowmask_gradw_parallel_into(
                    x.data(), dy.data(), m, d, n, &packed, threads, &mut gw_packed,
                );
            }),
        ),
    ] {
        let ratio = csr_s / packed_s.max(1e-12);
        if name == "forward" {
            fwd_ratio = ratio;
        }
        println!(
            "{:>12} {:>10.1}us {:>10.1}us {:>7.2}x",
            name,
            csr_s * 1e6,
            packed_s * 1e6,
            ratio
        );
        kernel_objs.push(obj(vec![
            ("kernel", Json::Str(name.to_string())),
            ("csr_secs", Json::Num(csr_s)),
            ("packed_secs", Json::Num(packed_s)),
            ("ratio", Json::Num(ratio)),
        ]));
    }
    println!(
        "mask bytes: packed {} vs csr {} (same selection)",
        packed.nbytes(),
        csr.nbytes()
    );

    let report = obj(vec![
        ("bench", Json::Str("fig_structured".to_string())),
        ("smoke", Json::Bool(smoke)),
        (
            "accuracy",
            obj(vec![
                ("model", Json::Str(spec.name.clone())),
                ("gamma", Json::Num(gamma as f64)),
                ("steps", Json::Num(steps as f64)),
                ("modes", Json::Arr(mode_objs)),
            ]),
        ),
        (
            "kernels",
            obj(vec![
                ("m", Json::Num(m as f64)),
                ("d", Json::Num(d as f64)),
                ("n", Json::Num(n as f64)),
                ("gamma", Json::Num(kgamma as f64)),
                ("k", Json::Num(k as f64)),
                ("threads", Json::Num(threads as f64)),
                ("reps", Json::Num(reps as f64)),
                ("packed_mask_bytes", Json::Num(packed.nbytes() as f64)),
                ("csr_mask_bytes", Json::Num(csr.nbytes() as f64)),
                ("forward_csr_over_packed", Json::Num(fwd_ratio)),
                ("rows", Json::Arr(kernel_objs)),
            ]),
        ),
    ]);
    let out_path =
        std::env::var("DSG_BENCH_OUT").unwrap_or_else(|_| "BENCH_structured.json".into());
    std::fs::write(&out_path, report.to_string())?;
    println!("\nwrote {out_path}");
    println!("fig_structured OK (packed/CSR bit parity held; accuracy + timing reported)");
    Ok(())
}
