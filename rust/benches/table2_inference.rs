//! Table 2: inference-time structured sparsification — DSG used as a
//! fine-tuning pass on a pre-trained model, reporting operation sparsity
//! vs accuracy against the published pruning baselines.
//!
//! Protocol (scaled to this testbed): train dense to convergence, then
//! fine-tune with DSG at the target sparsity; report the operation
//! sparsity (counting input + output zeros like the baselines do) and
//! the accuracy delta vs the dense model.  The baseline rows are quoted
//! from the paper for context.

use dsg::config::{GammaSchedule, RunConfig};
use dsg::coordinator::Trainer;
use dsg::runtime::{Meta, Runtime};

fn main() -> anyhow::Result<()> {
    dsg::benchutil::header(
        "Table 2",
        "inference pruning via DSG fine-tune vs published baselines (VGG16/ImageNet in paper)",
        "DSG: 62.92% op sparsity @ 71.44% top-1 — best acc/sparsity balance",
    );
    let rt = Runtime::cpu()?;
    let steps = dsg::benchutil::bench_steps();

    // dense pre-training
    let dir = dsg::artifacts_dir();
    let meta = Meta::load(&dir, "vgg8")?;
    let mut cfg = RunConfig::preset_for_model("vgg8");
    cfg.steps = steps * 2;
    cfg.eval_every = 0;
    let (train, test) = dsg::benchutil::data_for(&cfg);
    cfg.gamma = GammaSchedule::Constant(0.0);
    let mut t = Trainer::new(&rt, meta, cfg.seed)?;
    let dense_acc = t.train(&cfg, &train, &test)?;
    println!("\ndense vgg8 reference: acc {dense_acc:.3} after {} steps", cfg.steps);

    // DSG fine-tuning at increasing sparsity from the SAME weights
    println!(
        "\n{:<26} {:>12} {:>10} {:>10}",
        "method", "op sparsity", "acc", "acc delta"
    );
    for quoted in [
        ("Taylor Expansion (paper)", "62.86%", "87% (top5)"),
        ("ThiNet (paper)", "69.81%", "67.34%"),
        ("Channel Pruning (paper)", "69.32%", "70.42%"),
        ("AutoPrunner (paper)", "73.60%", "68.43%"),
        ("AMC (paper)", "80.00%", "69.1%"),
        ("DSG (paper)", "62.92%", "71.44%"),
    ] {
        println!("{:<26} {:>12} {:>10} {:>10}", quoted.0, quoted.1, quoted.2, "-");
    }
    for gamma in [0.5f32, 0.6, 0.7] {
        let mut ft = RunConfig::preset_for_model("vgg8");
        ft.steps = steps;
        ft.eval_every = 0;
        ft.lr = cfg.lr * 0.2; // fine-tune LR
        ft.gamma = GammaSchedule::Constant(gamma);
        let mut t2 = Trainer::new(&rt, t.meta.clone(), ft.seed)?;
        t2.state = t.state.clone(); // start from the dense weights
        t2.refresh_projection()?;
        let acc = t2.train(&ft, &train, &test)?;
        // operation sparsity counting input+output zeros like the
        // baselines: output sparsity gamma, input sparsity of next layer
        // is the same mask => ops removed ~ 1-(1-g)^2 on stacked layers,
        // conservatively reported as the measured mask sparsity.
        let dens = t2.history.mean_densities(20);
        let mask_sp = 1.0 - dens.iter().sum::<f32>() / dens.len() as f32;
        let op_sp = 1.0 - (1.0 - mask_sp) * (1.0 - 0.5 * mask_sp); // in+out zeros
        println!(
            "{:<26} {:>11.2}% {:>9.3} {:>+10.3}",
            format!("DSG fine-tune g={gamma}"),
            100.0 * op_sp,
            acc,
            acc - dense_acc
        );
    }
    println!("\n(baseline rows quoted from the paper; DSG rows measured on this testbed)");
    Ok(())
}
