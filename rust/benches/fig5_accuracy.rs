//! Fig 5(a): sparsity vs accuracy for the small/medium models (MLP,
//! LeNet, VGG8-lite, ResNet8) on the synthetic datasets.
//!
//! Expected shape: accuracy flat for gamma < 0.6, knee by 0.8-0.9; CNNs
//! tolerate more sparsity than the MLP; ResNet more sensitive than VGG.

use dsg::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    dsg::benchutil::header(
        "Fig 5(a)",
        "accuracy vs sparsity across the model zoo",
        "<60% sparsity ~free; abrupt descent >80%; CNN > MLP robustness",
    );
    let rt = Runtime::cpu()?;
    let steps = dsg::benchutil::bench_steps();
    let gammas = [0.0f32, 0.3, 0.5, 0.6, 0.7, 0.8, 0.9];
    let models: &[&str] = &["mlp", "lenet", "vgg8", "resnet8"];
    println!("steps per point: {steps} (set DSG_BENCH_STEPS to change)\n");
    for model in models {
        let mut series = Vec::new();
        for &g in &gammas {
            let (acc, _) = dsg::benchutil::train_at(&rt, model, g, steps, 7)?;
            series.push((g, acc));
        }
        dsg::benchutil::print_series(model, &series);
        let flat = series[0].1 - series[2].1; // gamma 0 vs 0.5
        let knee = series[2].1 - series[6].1; // gamma 0.5 vs 0.9
        println!(
            "    drop to 50%: {:.3}; drop 50%->90%: {:.3} (knee should dominate)",
            flat, knee
        );
    }
    Ok(())
}
