//! Serving throughput: baseline pump vs the single-queue concurrent
//! server vs the sharded work-stealing engine, on the synthetic DSG
//! model (real column-skipping engines, no artifacts required) — plus
//! the offered-load vs latency saturation sweep behind the serving
//! acceptance criterion.
//!
//! Three sections:
//!
//! 1. **Parity + scaling** — the SAME pre-enqueued load through the
//!    baseline pump, the `ConcurrentServer` at 1/2/4 workers, and the
//!    `ShardedServer` across shard counts; every run is asserted
//!    bit-identical to the baseline (concurrency and sharding change
//!    throughput, never results).
//! 2. **Saturation sweep** — offered load from 0.25x to 4x of measured
//!    capacity against a BOUNDED sharded server: p50/p99 queue latency
//!    and the served/rejected split per point.  Past saturation the
//!    curve reports explicit rejections at bounded latency instead of
//!    an unbounded-queue latency cliff.
//! 3. **Burst overload** — the whole load submitted at once into a
//!    tiny queue bound: rejections are deterministic and accounted
//!    (served + rejected == offered, nothing silently dropped).
//!
//! Writes machine-readable `BENCH_serve.json` (override the path with
//! `DSG_BENCH_OUT`) — uploaded by CI as the serving perf artifact.
//!
//!     cargo bench --bench serve_throughput
//!     DSG_SERVE_REQUESTS=4096 cargo bench --bench serve_throughput
//!     DSG_SERVE_SMOKE=1 cargo bench --bench serve_throughput   # CI: small load

use dsg::metrics::fmt_secs;
use dsg::serve::{
    Batcher, ConcurrentServer, Queue, ServerConfig, ShardedConfig, ShardedServer, SubmitError,
    SynthModel,
};
use dsg::sparse::parallel::n_threads;
use dsg::util::json::{obj, Json};
use std::sync::Arc;
use std::time::{Duration, Instant};

const DIMS: &[usize] = &[784, 512, 256];
const CLASSES: usize = 10;
const BATCH: usize = 32;
const GAMMA: f32 = 0.8;

fn model(intra: usize) -> Arc<SynthModel> {
    Arc::new(SynthModel::new(42, DIMS, CLASSES, GAMMA).with_intra_threads(intra))
}

/// One paced offered-load point against a bounded sharded server.
struct SweepPoint {
    multiplier: f64,
    offered_rate: f64,
    achieved: f64,
    served: usize,
    rejected: usize,
    p50: f64,
    p99: f64,
}

fn run_offered_load(
    images: &[Vec<f32>],
    shards: usize,
    workers: usize,
    intra: usize,
    offered_rate: f64,
    multiplier: f64,
    queue_cap: usize,
) -> anyhow::Result<SweepPoint> {
    let m = model(intra);
    let cfg = ShardedConfig::new(shards, workers, BATCH, DIMS[0], CLASSES)
        .with_max_wait(Duration::from_millis(2))
        .with_queue_cap(queue_cap);
    let srv = ShardedServer::start(cfg, move |xs: &[f32]| m.forward(xs, BATCH));
    let interval = Duration::from_secs_f64(1.0 / offered_rate.max(1.0));
    let start = Instant::now();
    let mut rejected = 0usize;
    for (i, img) in images.iter().enumerate() {
        // open-loop arrivals: stick to the schedule even when behind
        let target = start + interval * i as u32;
        if let Some(wait) = target.checked_duration_since(Instant::now()) {
            std::thread::sleep(wait);
        }
        match srv.submit(img.clone()) {
            Ok(_) => {}
            Err(SubmitError::Rejected(_)) => rejected += 1,
            Err(e) => anyhow::bail!("unexpected submit error: {e}"),
        }
    }
    srv.flush();
    let report = srv.join();
    anyhow::ensure!(report.failed == 0, "batches failed during the sweep");
    anyhow::ensure!(
        report.served + rejected == images.len(),
        "request conservation broken: {} served + {rejected} rejected != {}",
        report.served,
        images.len()
    );
    anyhow::ensure!(report.rejected as usize == rejected, "reject accounting diverged");
    Ok(SweepPoint {
        multiplier,
        offered_rate,
        achieved: report.throughput(),
        served: report.served,
        rejected,
        p50: report.latency.percentile(0.50),
        p99: report.latency.percentile(0.99),
    })
}

fn main() -> anyhow::Result<()> {
    dsg::benchutil::header(
        "serve",
        "serving throughput: single queue vs sharded engine, offered-load saturation sweep",
        "bit-identical predictions everywhere; rejections instead of an overload cliff",
    );
    let smoke = std::env::var("DSG_SERVE_SMOKE").is_ok();
    let requests: usize = std::env::var("DSG_SERVE_REQUESTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(if smoke { 192 } else { 1024 });
    let cores = n_threads();
    println!("requests {requests}, batch {BATCH}, gamma {GAMMA}, {cores} cores\n");

    let probe = SynthModel::new(42, DIMS, CLASSES, GAMMA);
    let images: Vec<Vec<f32>> = (0..requests).map(|i| probe.synth_image(9000 + i as u64)).collect();

    // ---- section 1: parity + scaling --------------------------------
    let mut queue = Queue::new();
    for img in &images {
        queue.push(img.clone());
    }
    let mut batcher = Batcher::new(BATCH, DIMS[0], CLASSES);
    let t0 = Instant::now();
    let baseline = batcher.pump(&mut queue, |xs| probe.forward(xs, BATCH))?;
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "{:<22} {:>10} {:>10} {:>10} {:>12} {:>8}",
        "config", "p50", "p95", "p99", "imgs/sec", "exact"
    );
    let ps = batcher.stats.percentiles(&[0.50, 0.95, 0.99]); // one sort
    println!(
        "{:<22} {:>10} {:>10} {:>10} {:>12.1} {:>8}",
        "baseline pump (1x1)",
        fmt_secs(ps[0]),
        fmt_secs(ps[1]),
        fmt_secs(ps[2]),
        batcher.stats.throughput(wall),
        "-"
    );
    let want: Vec<usize> = baseline.iter().map(|r| r.pred).collect();

    let mut tput_at = std::collections::BTreeMap::new();
    for workers in [1usize, 2, 4] {
        let intra = (cores / workers).max(1);
        let m = model(intra);
        let cfg = ServerConfig::new(workers, BATCH, DIMS[0], CLASSES)
            .with_max_wait(Duration::from_millis(5));
        // serve_all pre-enqueues + closes before workers spawn: batch
        // boundaries can't shift with timing, so exactness is structural
        let report = ConcurrentServer::serve_all(
            cfg,
            move |xs: &[f32]| m.forward(xs, BATCH),
            images.iter().cloned(),
        )?;
        let exact = report.predictions() == want;
        assert!(exact, "{workers}-worker predictions diverged from baseline");
        tput_at.insert(workers, report.throughput());
        println!(
            "{:<22} {:>10} {:>10} {:>10} {:>12.1} {:>8}",
            format!("{workers} workers x {intra}t"),
            fmt_secs(report.latency.percentile(0.50)),
            fmt_secs(report.latency.percentile(0.95)),
            fmt_secs(report.latency.percentile(0.99)),
            report.throughput(),
            if exact { "yes" } else { "NO" }
        );
    }

    // sharded engine across the shard axis at a fixed worker budget
    let shard_workers = cores.clamp(1, 4);
    let mut sharded_tput = Vec::new();
    let mut sharded_capacity = 0.0f64;
    for shards in [1usize, 2, 4] {
        let intra = (cores / shard_workers).max(1);
        let m = model(intra);
        let cfg = ShardedConfig::new(shards, shard_workers, BATCH, DIMS[0], CLASSES)
            .with_max_wait(Duration::from_millis(5));
        let report = ShardedServer::serve_all(
            cfg,
            move |xs: &[f32]| m.forward(xs, BATCH),
            images.iter().cloned(),
        )?;
        let exact = report.predictions() == want;
        assert!(exact, "{shards}-shard predictions diverged from baseline");
        println!(
            "{:<22} {:>10} {:>10} {:>10} {:>12.1} {:>8}",
            format!("{shards} shards x {shard_workers}w"),
            fmt_secs(report.latency.percentile(0.50)),
            fmt_secs(report.latency.percentile(0.95)),
            fmt_secs(report.latency.percentile(0.99)),
            report.throughput(),
            if exact { "yes" } else { "NO" }
        );
        sharded_capacity = sharded_capacity.max(report.throughput());
        sharded_tput.push((shards, report.throughput(), report.stolen));
    }

    let (t1, t4) = (tput_at[&1], tput_at[&4]);
    println!(
        "\n4 workers vs 1: {:.2}x throughput ({:.1} -> {:.1} imgs/sec), predictions bit-identical",
        t4 / t1,
        t1,
        t4
    );
    if cores > 1 && t4 <= t1 {
        println!("WARN: expected >1x scaling on {cores} cores");
    }

    // ---- section 2: offered-load saturation sweep -------------------
    let multipliers: &[f64] = if smoke { &[0.5, 2.0] } else { &[0.25, 0.5, 1.0, 2.0, 4.0] };
    let sweep_cap = 8; // blocks per shard: bounded latency past saturation
    println!(
        "\nsaturation sweep: capacity {:.1} imgs/sec, queue cap {sweep_cap} blocks/shard",
        sharded_capacity
    );
    println!(
        "{:<10} {:>12} {:>12} {:>8} {:>8} {:>10} {:>10}",
        "offered", "req/s", "achieved", "served", "rejected", "p50", "p99"
    );
    let mut sweep = Vec::new();
    for &mult in multipliers {
        let offered = (sharded_capacity * mult).max(1.0);
        let intra = (cores / shard_workers).max(1);
        let point = run_offered_load(&images, 2, shard_workers, intra, offered, mult, sweep_cap)?;
        println!(
            "{:<10} {:>12.1} {:>12.1} {:>8} {:>8} {:>10} {:>10}",
            format!("{mult}x"),
            point.offered_rate,
            point.achieved,
            point.served,
            point.rejected,
            fmt_secs(point.p50),
            fmt_secs(point.p99),
        );
        sweep.push(point);
    }

    // ---- section 3: burst overload ----------------------------------
    // the whole load at once into a 1-block cap: rejections must be
    // explicit and conserved, never a silent drop or unbounded queue
    let m = model(1);
    let burst_cfg = ShardedConfig::new(2, 1, BATCH, DIMS[0], CLASSES)
        .with_max_wait(Duration::from_millis(1))
        .with_queue_cap(1);
    let srv = ShardedServer::start(burst_cfg, move |xs: &[f32]| {
        std::thread::sleep(Duration::from_millis(2));
        m.forward(xs, BATCH)
    });
    let mut burst_rejected = 0usize;
    for img in &images {
        match srv.submit(img.clone()) {
            Ok(_) => {}
            Err(SubmitError::Rejected(_)) => burst_rejected += 1,
            Err(e) => anyhow::bail!("unexpected submit error: {e}"),
        }
    }
    srv.flush();
    let burst = srv.join();
    assert!(burst_rejected > 0, "an instantaneous burst past a 1-block cap must reject");
    assert_eq!(burst.served + burst_rejected, requests, "burst conservation broken");
    println!(
        "\nburst overload: {} offered at once -> {} served, {} rejected (explicit), p99 {}",
        requests,
        burst.served,
        burst_rejected,
        fmt_secs(burst.latency.percentile(0.99))
    );

    // ---- machine-readable artifact ----------------------------------
    let report = obj(vec![
        (
            "config",
            obj(vec![
                ("requests", Json::Num(requests as f64)),
                ("batch", Json::Num(BATCH as f64)),
                ("gamma", Json::Num(GAMMA as f64)),
                ("cores", Json::Num(cores as f64)),
                ("smoke", Json::Bool(smoke)),
            ]),
        ),
        (
            "parity",
            obj(vec![
                ("baseline_imgs_per_sec", Json::Num(batcher.stats.throughput(wall))),
                ("workers_1", Json::Num(tput_at[&1])),
                ("workers_2", Json::Num(tput_at[&2])),
                ("workers_4", Json::Num(tput_at[&4])),
                ("scaling_4v1", Json::Num(t4 / t1)),
                (
                    "sharded",
                    Json::Arr(
                        sharded_tput
                            .iter()
                            .map(|(s, t, stolen)| {
                                obj(vec![
                                    ("shards", Json::Num(*s as f64)),
                                    ("workers", Json::Num(shard_workers as f64)),
                                    ("imgs_per_sec", Json::Num(*t)),
                                    ("stolen_blocks", Json::Num(*stolen as f64)),
                                ])
                            })
                            .collect(),
                    ),
                ),
                ("bit_identical", Json::Bool(true)),
            ]),
        ),
        (
            "saturation_sweep",
            Json::Arr(
                sweep
                    .iter()
                    .map(|p| {
                        obj(vec![
                            ("multiplier", Json::Num(p.multiplier)),
                            ("offered_per_sec", Json::Num(p.offered_rate)),
                            ("achieved_per_sec", Json::Num(p.achieved)),
                            ("served", Json::Num(p.served as f64)),
                            ("rejected", Json::Num(p.rejected as f64)),
                            ("p50_secs", Json::Num(p.p50)),
                            ("p99_secs", Json::Num(p.p99)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "burst_overload",
            obj(vec![
                ("offered", Json::Num(requests as f64)),
                ("served", Json::Num(burst.served as f64)),
                ("rejected", Json::Num(burst_rejected as f64)),
                ("queue_cap_blocks", Json::Num(1.0)),
                ("p99_secs", Json::Num(burst.latency.percentile(0.99))),
            ]),
        ),
    ]);
    let out_path = std::env::var("DSG_BENCH_OUT").unwrap_or_else(|_| "BENCH_serve.json".into());
    std::fs::write(&out_path, report.to_string())?;
    println!("\nwrote {out_path}");
    println!("serve_throughput OK (all configs bit-identical, overload rejects explicitly)");
    Ok(())
}
