//! Serving throughput: the concurrent multi-worker server vs the
//! single-threaded baseline pump, on the synthetic DSG model (real
//! column-skipping engines, no artifacts required).
//!
//! For each worker count the SAME pre-enqueued load is served and the
//! predictions are checked bit-identical against workers=1 — the
//! demonstration behind the serve acceptance criterion: concurrency
//! changes throughput, never results.
//!
//!     cargo bench --bench serve_throughput
//!     DSG_SERVE_REQUESTS=4096 cargo bench --bench serve_throughput

use dsg::metrics::fmt_secs;
use dsg::serve::{Batcher, ConcurrentServer, Queue, ServerConfig, SynthModel};
use dsg::sparse::parallel::n_threads;
use std::sync::Arc;
use std::time::Duration;

const DIMS: &[usize] = &[784, 512, 256];
const CLASSES: usize = 10;
const BATCH: usize = 32;
const GAMMA: f32 = 0.8;

fn main() -> anyhow::Result<()> {
    dsg::benchutil::header(
        "serve",
        "concurrent serving throughput: N workers over the shared request queue",
        "strictly higher imgs/sec at 4 workers than 1, identical predictions",
    );
    let requests: usize = std::env::var("DSG_SERVE_REQUESTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1024);
    let cores = n_threads();
    println!("requests {requests}, batch {BATCH}, gamma {GAMMA}, {cores} cores\n");

    let probe = SynthModel::new(42, DIMS, CLASSES, GAMMA);
    let images: Vec<Vec<f32>> = (0..requests).map(|i| probe.synth_image(9000 + i as u64)).collect();

    // Baseline: the deterministic single-threaded pump, serial engines.
    let mut queue = Queue::new();
    for img in &images {
        queue.push(img.clone());
    }
    let mut batcher = Batcher::new(BATCH, DIMS[0], CLASSES);
    let t0 = std::time::Instant::now();
    let baseline = batcher.pump(&mut queue, |xs| probe.forward(xs, BATCH))?;
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "{:<22} {:>10} {:>10} {:>10} {:>12} {:>8}",
        "config", "p50", "p95", "p99", "imgs/sec", "exact"
    );
    let ps = batcher.stats.percentiles(&[0.50, 0.95, 0.99]); // one sort
    println!(
        "{:<22} {:>10} {:>10} {:>10} {:>12.1} {:>8}",
        "baseline pump (1x1)",
        fmt_secs(ps[0]),
        fmt_secs(ps[1]),
        fmt_secs(ps[2]),
        batcher.stats.throughput(wall),
        "-"
    );
    let want: Vec<usize> = baseline.iter().map(|r| r.pred).collect();

    let mut tput_at = std::collections::BTreeMap::new();
    for workers in [1usize, 2, 4] {
        let intra = (cores / workers).max(1);
        let model =
            Arc::new(SynthModel::new(42, DIMS, CLASSES, GAMMA).with_intra_threads(intra));
        let cfg = ServerConfig::new(workers, BATCH, DIMS[0], CLASSES)
            .with_max_wait(Duration::from_millis(5));
        // serve_all pre-enqueues + closes before workers spawn: batch
        // boundaries can't shift with timing, so exactness is structural
        let report = ConcurrentServer::serve_all(
            cfg,
            move |xs: &[f32]| model.forward(xs, BATCH),
            images.iter().cloned(),
        )?;
        let exact = report.predictions() == want;
        assert!(exact, "{workers}-worker predictions diverged from baseline");
        tput_at.insert(workers, report.throughput());
        println!(
            "{:<22} {:>10} {:>10} {:>10} {:>12.1} {:>8}",
            format!("{workers} workers x {intra}t"),
            fmt_secs(report.latency.percentile(0.50)),
            fmt_secs(report.latency.percentile(0.95)),
            fmt_secs(report.latency.percentile(0.99)),
            report.throughput(),
            if exact { "yes" } else { "NO" }
        );
    }

    let (t1, t4) = (tput_at[&1], tput_at[&4]);
    println!(
        "\n4 workers vs 1: {:.2}x throughput ({:.1} -> {:.1} imgs/sec), predictions bit-identical",
        t4 / t1,
        t1,
        t4
    );
    if cores > 1 && t4 <= t1 {
        println!("WARN: expected >1x scaling on {cores} cores");
    }
    println!("serve_throughput OK");
    Ok(())
}
