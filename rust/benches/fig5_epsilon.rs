//! Fig 5(d): influence of the dimension-reduction degree eps — lower eps
//! means larger projected dimension k, more accurate inner-product
//! estimates, better accuracy, but more search compute (Table 1).

use dsg::costmodel::jll;
use dsg::runtime::{Meta, Runtime};

fn main() -> anyhow::Result<()> {
    dsg::benchutil::header(
        "Fig 5(d)",
        "accuracy vs sparsity for eps in {0.3, 0.5, 0.7, 0.9} on vgg8s",
        "eps=0.5: <1% loss up to 80% sparsity; higher eps degrades earlier",
    );
    let rt = Runtime::cpu()?;
    let dir = dsg::artifacts_dir();
    let steps = dsg::benchutil::bench_steps();
    let gammas = [0.0f32, 0.5, 0.8, 0.9];
    for (label, variant, eps) in [
        ("eps 0.3", "vgg8s_eps30", 0.3),
        ("eps 0.5", "vgg8s", 0.5),
        ("eps 0.7", "vgg8s_eps70", 0.7),
        ("eps 0.9", "vgg8s_eps90", 0.9),
    ] {
        let meta = Meta::load(&dir, variant)?;
        let k_example = meta.dsg_layers.iter().map(|l| l.k).max().unwrap_or(0);
        let mut series = Vec::new();
        for &g in &gammas {
            let (acc, _) = dsg::benchutil::train_at(&rt, variant, g, steps, 7)?;
            series.push((g, acc));
        }
        dsg::benchutil::print_series(label, &series);
        println!(
            "    max k {k_example}; search cost scales with k (Table 1): k(nK=256) = {}",
            jll::projection_dim(eps, 256, 2304)
        );
    }
    Ok(())
}
