//! Fig 8(a): layer-wise execution time on the CPU sparse engine — DSG's
//! vector-wise column skipping vs the row-loop VMM and blocked GEMM
//! baselines, on the five VGG8 layer shapes.
//!
//! Per the paper's protocol the DSG time is measured AFTER the
//! dimension-reduction search; the search time is reported alongside.

use dsg::metrics::fmt_secs;
use dsg::sparse::engine::{bench_layer, VGG8_LAYERS};

fn main() {
    dsg::benchutil::header(
        "Fig 8(a)",
        "layer execution time: DSG vs VMM vs GEMM (rust engine, MKL substitute)",
        "avg speedup vs VMM 2.0x/5.0x/8.5x; vs GEMM 0.6x/1.6x/2.7x at 50/80/90%",
    );
    let reps = std::env::var("DSG_BENCH_REPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(5);
    for &gamma in &[0.5f32, 0.8, 0.9] {
        println!("\n--- sparsity {:.0}% ---", gamma * 100.0);
        println!(
            "{:<8} {:>10} {:>10} {:>10} {:>10} {:>8} {:>8} {:>8}",
            "layer", "GEMM", "VMM", "DSG", "DRS", "vs-VMM", "vs-GEMM", "density"
        );
        let (mut sv, mut sg) = (0.0, 0.0);
        for (i, &shape) in VGG8_LAYERS.iter().enumerate() {
            let t = bench_layer(shape, gamma, 0.5, reps, 40 + i as u64);
            sv += t.speedup_vs_vmm();
            sg += t.speedup_vs_gemm();
            println!(
                "{:<8} {:>10} {:>10} {:>10} {:>10} {:>7.2}x {:>7.2}x {:>8.2}",
                shape.name,
                fmt_secs(t.gemm_secs),
                fmt_secs(t.vmm_secs),
                fmt_secs(t.dsg_secs),
                fmt_secs(t.drs_secs),
                t.speedup_vs_vmm(),
                t.speedup_vs_gemm(),
                t.density
            );
        }
        let n = VGG8_LAYERS.len() as f64;
        println!("average: vs VMM {:.2}x, vs GEMM {:.2}x", sv / n, sg / n);
    }

    whole_model_native();
}

/// Whole-model complement: the same column-skipping engine end-to-end on
/// the vgg8 artifact topology (native engine, host-side projection).
fn whole_model_native() {
    use dsg::native::{project_host, Mode, NativeModel};
    let dir = dsg::artifacts_dir();
    let Ok(meta) = dsg::runtime::Meta::load(&dir, "vgg8") else {
        println!("\n(whole-model section skipped: artifacts not built)");
        return;
    };
    if meta.units.is_empty() {
        println!("\n(whole-model section skipped: meta has no topology)");
        return;
    }
    let mut state = dsg::coordinator::ModelState::init(&meta, 7);
    project_host(&meta, &mut state).unwrap();
    let native = NativeModel::new(&meta, &state).unwrap();
    let mut rng = dsg::Pcg32::seeded(8);
    let mut shape = vec![meta.batch];
    shape.extend_from_slice(&meta.input_shape);
    let n: usize = shape.iter().product();
    let x = dsg::Tensor::new(&shape, rng.normal_vec(n, 1.0));

    println!("\n--- whole-model native engine (vgg8, batch {}) ---", meta.batch);
    let t0 = std::time::Instant::now();
    let dense = native.forward(&x, 0.0, Mode::Dense).unwrap();
    let t_dense = t0.elapsed().as_secs_f64();
    println!(
        "{:>8} {:>12} {:>12} {:>12} {:>9}",
        "gamma", "exec", "drs", "total", "vs-dense"
    );
    println!(
        "{:>8} {:>12} {:>12} {:>12} {:>9}",
        "dense",
        dsg::metrics::fmt_secs(t_dense),
        "-",
        dsg::metrics::fmt_secs(t_dense),
        "1.00x"
    );
    let _ = dense;
    for gamma in [0.5f32, 0.8, 0.9] {
        let t0 = std::time::Instant::now();
        let out = native.forward(&x, gamma, Mode::Dsg).unwrap();
        let total = t0.elapsed().as_secs_f64();
        let drs: f64 = out.stats.iter().map(|s| s.drs_secs).sum();
        let exec = total - drs;
        println!(
            "{:>8} {:>12} {:>12} {:>12} {:>8.2}x",
            gamma,
            dsg::metrics::fmt_secs(exec),
            dsg::metrics::fmt_secs(drs),
            dsg::metrics::fmt_secs(total),
            t_dense / exec
        );
    }
}
