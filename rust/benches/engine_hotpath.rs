//! Engine hot-path microbench: what did the pool + RowMask rebuild buy,
//! and what does compound sparsity buy on top?
//!
//! Three controlled comparisons at Fig 8(a)-style layer shapes, plus a
//! dispatch-overhead probe:
//!
//! * **spawn vs pool** — the identical chunk kernel dispatched through
//!   per-call `std::thread::scope` spawns (the old engines, reproduced
//!   verbatim below) vs the persistent `sparse::pool::WorkerPool`.
//! * **dense mask vs RowMask** — the masked VMM branch-scanning a dense
//!   f32 mask vs jumping through the compact per-row index lists.
//! * **output-sparse vs COMPOUND** — at the paper's gamma = 0.5
//!   operating point with a realistically sparse input (previous-layer
//!   mask + ReLU), the kernels that also skip the input-side zeros.
//!   Realized multiply-adds are counted by the kernels themselves and
//!   asserted: compound <= output-sparse, and the gamma-0.5 reduction
//!   must clear 1.5x (the Fig 8/9 (1-gamma)^2 claim, measured).
//! * **scalar vs SIMD** — the scalar kernel table vs the
//!   runtime-detected one (`--kernels simd`) on the masked forward at
//!   threads = 1: GFLOP/s-per-core both ways plus the speedup, ULP-gated
//!   against the scalar contract before timing.  On AVX2 hardware in
//!   full (non-smoke) mode the total speedup must clear 1x.
//!
//! Every variant is asserted bit-identical before timing — the rebuild
//! must change WHERE time goes, never a single output bit.  (The SIMD
//! section is the one deliberate exception: its forward dots are gated
//! by the documented ULP bound instead.)
//!
//! Writes machine-readable `BENCH_hotpath.json` (override the path with
//! `DSG_BENCH_OUT`) — the perf trajectory artifact CI uploads.
//!
//!     cargo bench --bench engine_hotpath
//!     DSG_HOTPATH_SMOKE=1 cargo bench --bench engine_hotpath   # CI: tiny shapes
//!     DSG_BENCH_REPS=9 cargo bench --bench engine_hotpath

use dsg::drs::projection::{ternary_r, TernaryIndex};
use dsg::drs::topk::{self, RowMask};
use dsg::metrics::fmt_secs;
use dsg::sparse::parallel;
use dsg::tensor::{ops, Tensor};
use dsg::util::json::{obj, Json};
use dsg::util::{time_secs, Pcg32};

// ---------------------------------------------------------------------------
// The OLD scoped-thread engines, reproduced verbatim as the baseline.
// Same chunking, same inner kernels — the only difference from the pool
// path is the per-dispatch thread spawn/join.
// ---------------------------------------------------------------------------

fn row_chunks(rows: usize, parts: usize) -> Vec<(usize, usize)> {
    let parts = parts.min(rows).max(1);
    let base = rows / parts;
    let extra = rows % parts;
    let mut out = Vec::with_capacity(parts);
    let mut start = 0;
    for i in 0..parts {
        let len = base + usize::from(i < extra);
        out.push((start, start + len));
        start += len;
    }
    out
}

fn matmul_spawn(x: &Tensor, w: &Tensor, threads: usize) -> Tensor {
    let (m, k) = (x.shape()[0], x.shape()[1]);
    let (k2, n) = (w.shape()[0], w.shape()[1]);
    assert_eq!(k, k2);
    let mut out = vec![0.0f32; m * n];
    let chunks = row_chunks(m, threads.max(1));
    let xd = x.data();
    let wd = w.data();
    std::thread::scope(|scope| {
        let mut remaining: &mut [f32] = &mut out;
        for &(lo, hi) in &chunks {
            let (mine, rest) = remaining.split_at_mut((hi - lo) * n);
            remaining = rest;
            scope.spawn(move || {
                const KC: usize = 256;
                for p0 in (0..k).step_by(KC) {
                    let p1 = (p0 + KC).min(k);
                    for i in lo..hi {
                        let arow = &xd[i * k..(i + 1) * k];
                        let orow = &mut mine[(i - lo) * n..(i - lo + 1) * n];
                        for p in p0..p1 {
                            let av = arow[p];
                            if av == 0.0 {
                                continue;
                            }
                            let brow = &wd[p * n..(p + 1) * n];
                            let mut j = 0;
                            while j + 4 <= n {
                                orow[j] += av * brow[j];
                                orow[j + 1] += av * brow[j + 1];
                                orow[j + 2] += av * brow[j + 2];
                                orow[j + 3] += av * brow[j + 3];
                                j += 4;
                            }
                            while j < n {
                                orow[j] += av * brow[j];
                                j += 1;
                            }
                        }
                    }
                }
            });
        }
    });
    Tensor::new(&[m, n], out)
}

fn dsg_vmm_spawn(x: &Tensor, wt: &Tensor, mask: &Tensor, threads: usize) -> Tensor {
    let (m, d) = (x.shape()[0], x.shape()[1]);
    let (n, d2) = (wt.shape()[0], wt.shape()[1]);
    assert_eq!(d, d2);
    assert_eq!(mask.shape(), &[m, n]);
    let mut out = vec![0.0f32; m * n];
    let chunks = row_chunks(m, threads.max(1));
    let xd = x.data();
    let wd = wt.data();
    let md = mask.data();
    std::thread::scope(|scope| {
        let mut remaining: &mut [f32] = &mut out;
        for &(lo, hi) in &chunks {
            let (mine, rest) = remaining.split_at_mut((hi - lo) * n);
            remaining = rest;
            scope.spawn(move || {
                for i in lo..hi {
                    let row = &xd[i * d..(i + 1) * d];
                    let mrow = &md[i * n..(i + 1) * n];
                    let orow = &mut mine[(i - lo) * n..(i - lo + 1) * n];
                    for j in 0..n {
                        if mrow[j] == 0.0 {
                            continue;
                        }
                        let wrow = &wd[j * d..(j + 1) * d];
                        let mut acc = 0.0f32;
                        let mut p = 0;
                        while p + 4 <= d {
                            acc += row[p] * wrow[p]
                                + row[p + 1] * wrow[p + 1]
                                + row[p + 2] * wrow[p + 2]
                                + row[p + 3] * wrow[p + 3];
                            p += 4;
                        }
                        while p < d {
                            acc += row[p] * wrow[p];
                            p += 1;
                        }
                        orow[j] = acc;
                    }
                }
            });
        }
    });
    Tensor::new(&[m, n], out)
}

// ---------------------------------------------------------------------------

fn median(mut xs: Vec<f64>) -> f64 {
    xs.sort_by(|a, b| a.total_cmp(b));
    xs[xs.len() / 2]
}

fn time_median(reps: usize, mut f: impl FnMut()) -> f64 {
    let mut ts = Vec::with_capacity(reps);
    for _ in 0..reps {
        let ((), t) = time_secs(&mut f);
        ts.push(t);
    }
    median(ts)
}

struct Shape {
    name: &'static str,
    m: usize,
    d: usize,
    n: usize,
}

fn main() -> anyhow::Result<()> {
    dsg::benchutil::header(
        "hotpath",
        "spawn-vs-pool dispatch and dense-mask-vs-RowMask at Fig 8a layer shapes",
        "pool + RowMask strictly faster than spawn + dense mask, bit-identical outputs",
    );
    let smoke = std::env::var("DSG_HOTPATH_SMOKE").is_ok();
    let reps: usize = std::env::var("DSG_BENCH_REPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(if smoke { 3 } else { 5 });
    let threads = parallel::n_threads();
    let gamma = 0.9f32;
    let shapes: Vec<Shape> = if smoke {
        vec![
            Shape { name: "tiny1", m: 48, d: 96, n: 32 },
            Shape { name: "tiny2", m: 32, d: 128, n: 24 },
        ]
    } else {
        dsg::sparse::engine::VGG8_LAYERS
            .iter()
            .map(|l| Shape { name: l.name, m: l.n_pq, d: l.n_crs, n: l.n_k })
            .collect()
    };
    println!(
        "threads {threads}, reps {reps}, gamma {gamma}{}\n",
        if smoke { " (smoke shapes)" } else { "" }
    );
    println!(
        "{:<8} {:>11} {:>11} {:>11} {:>11} {:>8} {:>9} {:>9}",
        "layer", "mm-spawn", "mm-pool", "vmm-dense", "vmm-rowmsk", "density", "dispatch", "maskfmt"
    );

    let mut layer_objs: Vec<Json> = Vec::new();
    let (mut base_total, mut new_total) = (0.0f64, 0.0f64);
    for (si, s) in shapes.iter().enumerate() {
        let mut rng = Pcg32::seeded(300 + si as u64);
        let (m, d, n) = (s.m, s.d, s.n);
        let x = Tensor::new(&[m, d], rng.normal_vec(m * d, 1.0));
        let w = Tensor::new(&[d, n], rng.normal_vec(d * n, (2.0 / d as f32).sqrt()));
        let wt = ops::transpose(&w);
        // DRS selection at `gamma`, built once (the Fig 8a protocol
        // times the layer AFTER the search)
        let k = dsg::costmodel::jll::projection_dim(0.5, n, d);
        let r = ternary_r(&mut rng, k, d, 3);
        let ridx = TernaryIndex::from_dense(&r);
        let wp = dsg::drs::project_weights_idx(&ridx, &w);
        let xp = parallel::project_rows_parallel_with(&x, &ridx, 1);
        let virt = ops::matmul_blocked(&xp, &wp);
        let thr = topk::shared_threshold(&virt, gamma);
        let dense_mask =
            Tensor::from_fn(virt.shape(), |i| if virt.data()[i] >= thr { 1.0 } else { 0.0 });
        let rowmask = RowMask::from_threshold(&virt, thr);

        // --- exactness gate: the rebuild may not change a single bit ---
        let mm_spawn = matmul_spawn(&x, &w, threads);
        let mm_pool = parallel::matmul_parallel_with(&x, &w, threads);
        assert_eq!(mm_spawn, mm_pool, "{}: pool matmul != spawn matmul", s.name);
        assert_eq!(
            mm_pool,
            parallel::matmul_parallel_with(&x, &w, 1),
            "{}: pool matmul not budget-invariant",
            s.name
        );
        let vmm_spawn = dsg_vmm_spawn(&x, &wt, &dense_mask, threads);
        let vmm_dense = parallel::dsg_vmm_parallel_with(&x, &wt, &dense_mask, threads);
        let vmm_rm = parallel::dsg_vmm_rowmask_parallel_with(&x, &wt, &rowmask, threads);
        assert_eq!(vmm_spawn, vmm_dense, "{}: pool vmm != spawn vmm", s.name);
        assert_eq!(vmm_dense, vmm_rm, "{}: RowMask vmm != dense-mask vmm", s.name);
        assert_eq!(
            vmm_rm,
            dsg::sparse::dsg_vmm_rowmask(&x, &wt, &rowmask),
            "{}: parallel RowMask vmm != serial",
            s.name
        );

        // --- timings ---
        let mm_spawn_secs = time_median(reps, || {
            let _ = matmul_spawn(&x, &w, threads);
        });
        let mm_pool_secs = time_median(reps, || {
            let _ = parallel::matmul_parallel_with(&x, &w, threads);
        });
        let vmm_dense_secs = time_median(reps, || {
            let _ = parallel::dsg_vmm_parallel_with(&x, &wt, &dense_mask, threads);
        });
        let vmm_rm_secs = time_median(reps, || {
            let _ = parallel::dsg_vmm_rowmask_parallel_with(&x, &wt, &rowmask, threads);
        });
        let vmm_spawn_secs = time_median(reps, || {
            let _ = dsg_vmm_spawn(&x, &wt, &dense_mask, threads);
        });
        base_total += mm_spawn_secs + vmm_spawn_secs;
        new_total += mm_pool_secs + vmm_rm_secs;
        println!(
            "{:<8} {:>11} {:>11} {:>11} {:>11} {:>8.3} {:>8.2}x {:>8.2}x",
            s.name,
            fmt_secs(mm_spawn_secs),
            fmt_secs(mm_pool_secs),
            fmt_secs(vmm_dense_secs),
            fmt_secs(vmm_rm_secs),
            rowmask.density(),
            mm_spawn_secs / mm_pool_secs,
            vmm_dense_secs / vmm_rm_secs,
        );
        layer_objs.push(obj(vec![
            ("name", Json::Str(s.name.to_string())),
            ("m", Json::Num(m as f64)),
            ("d", Json::Num(d as f64)),
            ("n", Json::Num(n as f64)),
            ("gamma", Json::Num(gamma as f64)),
            ("density", Json::Num(rowmask.density())),
            ("matmul_spawn_secs", Json::Num(mm_spawn_secs)),
            ("matmul_pool_secs", Json::Num(mm_pool_secs)),
            ("vmm_spawn_dense_secs", Json::Num(vmm_spawn_secs)),
            ("vmm_pool_dense_secs", Json::Num(vmm_dense_secs)),
            ("vmm_pool_rowmask_secs", Json::Num(vmm_rm_secs)),
            ("exact", Json::Bool(true)),
        ]));
    }

    // --- compound-sparsity section: the Fig 8a shapes at the paper's
    // gamma = 0.5 with a REALISTIC input (previous layer's mask + ReLU
    // zeros), ops-counted dense vs output-sparse vs compound ---
    let g_both = 0.5f32;
    println!(
        "\ncompound sparsity @ gamma {g_both} in AND out (input = prev mask + relu):"
    );
    println!(
        "{:<8} {:>11} {:>11} {:>11} {:>8} {:>8} {:>8}",
        "layer", "gemm", "vmm-outsp", "vmm-cmpnd", "in-dens", "ops-x", "time-x"
    );
    let mut compound_objs: Vec<Json> = Vec::new();
    let (mut os_ops_total, mut comp_ops_total) = (0u64, 0u64);
    for (si, s) in shapes.iter().enumerate() {
        let mut rng = Pcg32::seeded(600 + si as u64);
        let (m, d, n) = (s.m, s.d, s.n);
        // simulate the previous layer: a gamma=0.5 selection zeroes half
        // the input coordinates, relu kills half of the survivors
        let mut xv = rng.normal_vec(m * d, 1.0);
        let prev_virt = Tensor::new(&[m, d], rng.normal_vec(m * d, 1.0));
        let in_mask = topk::select_rowmask(&prev_virt, g_both).to_dense();
        for (v, mk) in xv.iter_mut().zip(in_mask.data()) {
            if *mk == 0.0 || *v < 0.0 {
                *v = 0.0;
            }
        }
        let x = Tensor::new(&[m, d], xv);
        let in_density =
            x.data().iter().filter(|v| **v != 0.0).count() as f64 / (m * d) as f64;
        let w = Tensor::new(&[d, n], rng.normal_vec(d * n, (2.0 / d as f32).sqrt()));
        let wt = ops::transpose(&w);
        // DRS selection at gamma = 0.5 on the sparse input
        let k = dsg::costmodel::jll::projection_dim(0.5, n, d);
        let r = ternary_r(&mut rng, k, d, 3);
        let ridx = TernaryIndex::from_dense(&r);
        let wp = dsg::drs::project_weights_idx(&ridx, &w);
        let xp = parallel::project_rows_parallel_with(&x, &ridx, 1);
        let virt = ops::matmul_blocked(&xp, &wp);
        let thr = topk::shared_threshold(&virt, g_both);
        let rowmask = RowMask::from_threshold(&virt, thr);

        // --- exactness + realized-ops gates ---
        let want = parallel::dsg_vmm_rowmask_parallel_with(&x, &wt, &rowmask, threads);
        let (got, realized) =
            parallel::dsg_vmm_compound_parallel_with(&x, &wt, &rowmask, in_density as f32, threads);
        assert_eq!(want, got, "{}: compound vmm != output-sparse vmm", s.name);
        for t in [1usize, 2, 3, 8] {
            let (bt, rt) =
                parallel::dsg_vmm_compound_parallel_with(&x, &wt, &rowmask, in_density as f32, t);
            assert_eq!(want, bt, "{}: compound not budget-invariant @ {t}", s.name);
            assert_eq!(realized, rt, "{}: realized count not budget-invariant @ {t}", s.name);
        }
        let (serial, _) = dsg::sparse::dsg_vmm_compound(&x, &wt, &rowmask);
        assert_eq!(want, serial, "{}: serial compound != parallel", s.name);
        let os_ops = d as u64 * rowmask.selected() as u64;
        let dense_ops = (m * d * n) as u64;
        assert!(
            realized <= os_ops,
            "{}: compound realized {realized} > output-sparse {os_ops}",
            s.name
        );
        let ops_x = os_ops as f64 / realized.max(1) as f64;
        assert!(
            ops_x >= 1.5,
            "{}: realized-ops reduction {ops_x:.2}x below the 1.5x gate \
             (in-density {in_density:.3})",
            s.name
        );
        os_ops_total += os_ops;
        comp_ops_total += realized;

        // --- timings ---
        let gemm_secs = time_median(reps, || {
            let _ = parallel::matmul_parallel_with(&x, &w, threads);
        });
        let os_secs = time_median(reps, || {
            let _ = parallel::dsg_vmm_rowmask_parallel_with(&x, &wt, &rowmask, threads);
        });
        let comp_secs = time_median(reps, || {
            let _ = parallel::dsg_vmm_compound_parallel_with(
                &x, &wt, &rowmask, in_density as f32, threads,
            );
        });
        println!(
            "{:<8} {:>11} {:>11} {:>11} {:>8.3} {:>7.2}x {:>7.2}x",
            s.name,
            fmt_secs(gemm_secs),
            fmt_secs(os_secs),
            fmt_secs(comp_secs),
            in_density,
            ops_x,
            os_secs / comp_secs,
        );
        compound_objs.push(obj(vec![
            ("name", Json::Str(s.name.to_string())),
            ("m", Json::Num(m as f64)),
            ("d", Json::Num(d as f64)),
            ("n", Json::Num(n as f64)),
            ("gamma", Json::Num(g_both as f64)),
            ("in_density", Json::Num(in_density)),
            ("out_density", Json::Num(rowmask.density())),
            ("dense_madds", Json::Num(dense_ops as f64)),
            ("output_sparse_madds", Json::Num(os_ops as f64)),
            ("compound_madds", Json::Num(realized as f64)),
            ("ops_reduction_vs_output_sparse", Json::Num(ops_x)),
            ("ops_reduction_vs_dense", Json::Num(dense_ops as f64 / realized.max(1) as f64)),
            ("gemm_secs", Json::Num(gemm_secs)),
            ("vmm_output_sparse_secs", Json::Num(os_secs)),
            ("vmm_compound_secs", Json::Num(comp_secs)),
            ("time_speedup_vs_output_sparse", Json::Num(os_secs / comp_secs)),
            ("exact", Json::Bool(true)),
        ]));
    }
    let total_ops_x = os_ops_total as f64 / comp_ops_total.max(1) as f64;
    println!(
        "compound realized ops: {} vs output-sparse {} -> {:.2}x @ gamma {g_both}",
        comp_ops_total, os_ops_total, total_ops_x
    );
    assert!(total_ops_x >= 1.5, "total realized-ops reduction {total_ops_x:.2}x < 1.5x");

    // --- SIMD section: the scalar table vs the runtime-detected table
    // (`--kernels simd`) on the vmm_dot-dominated masked forward, at
    // threads = 1 so GFLOP/s is per-core by construction.  Outputs are
    // ULP-gated against the scalar contract (sampled rows, exact bound)
    // before anything is timed. ---
    let simd_isa = parallel::active_kernels().isa;
    println!("\nsimd kernels (detected: {}) @ threads 1, gamma {g_both}:", simd_isa.label());
    println!(
        "{:<8} {:>11} {:>11} {:>9} {:>9} {:>8}",
        "layer", "vmm-scalar", "vmm-simd", "sc-GF/s", "simd-GF/s", "speedup"
    );
    let mut simd_objs: Vec<Json> = Vec::new();
    let (mut simd_scalar_total, mut simd_simd_total) = (0.0f64, 0.0f64);
    for (si, s) in shapes.iter().enumerate() {
        let mut rng = Pcg32::seeded(900 + si as u64);
        let (m, d, n) = (s.m, s.d, s.n);
        let x = Tensor::new(&[m, d], rng.normal_vec(m * d, 1.0));
        let w = Tensor::new(&[d, n], rng.normal_vec(d * n, (2.0 / d as f32).sqrt()));
        let wt = ops::transpose(&w);
        let virt = Tensor::new(&[m, n], rng.normal_vec(m * n, 1.0));
        let rowmask = topk::select_rowmask(&virt, g_both);
        let madds = d as u64 * rowmask.selected() as u64;

        // --- ULP gate: per-element divergence within the documented
        // bound on a row sample (the full sweep is O(m*n*d) — one extra
        // unmeasured forward per sampled row) ---
        let mut scalar_out = vec![0.0f32; m * n];
        let mut simd_out = vec![0.0f32; m * n];
        parallel::dsg_vmm_rowmask_parallel_into_kt(
            parallel::scalar_kernels(),
            x.data(),
            m,
            d,
            wt.data(),
            n,
            &rowmask,
            1,
            &mut scalar_out,
        );
        parallel::dsg_vmm_rowmask_parallel_into_kt(
            parallel::active_kernels(),
            x.data(),
            m,
            d,
            wt.data(),
            n,
            &rowmask,
            1,
            &mut simd_out,
        );
        for i in 0..m.min(16) {
            for &j in rowmask.row(i) {
                let (a, b) = (scalar_out[i * n + j as usize], simd_out[i * n + j as usize]);
                let mag: f64 = (0..d)
                    .map(|q| {
                        (x.data()[i * d + q] as f64 * wt.data()[j as usize * d + q] as f64).abs()
                    })
                    .sum();
                let bound = 4.0 * d as f64 * f32::EPSILON as f64 * mag + f32::MIN_POSITIVE as f64;
                let err = (a as f64 - b as f64).abs();
                assert!(
                    err <= bound,
                    "{}: simd dot ({i},{j}) err {err} > ULP bound {bound}",
                    s.name
                );
            }
        }

        // --- timings (threads = 1: per-core numbers) ---
        let scalar_secs = time_median(reps, || {
            parallel::dsg_vmm_rowmask_parallel_into_kt(
                parallel::scalar_kernels(),
                x.data(),
                m,
                d,
                wt.data(),
                n,
                &rowmask,
                1,
                &mut scalar_out,
            );
        });
        let simd_secs = time_median(reps, || {
            parallel::dsg_vmm_rowmask_parallel_into_kt(
                parallel::active_kernels(),
                x.data(),
                m,
                d,
                wt.data(),
                n,
                &rowmask,
                1,
                &mut simd_out,
            );
        });
        simd_scalar_total += scalar_secs;
        simd_simd_total += simd_secs;
        // 2 flops per multiply-add, one core: GFLOP/s-per-core
        let scalar_gflops = 2.0 * madds as f64 / scalar_secs / 1e9;
        let simd_gflops = 2.0 * madds as f64 / simd_secs / 1e9;
        println!(
            "{:<8} {:>11} {:>11} {:>9.2} {:>9.2} {:>7.2}x",
            s.name,
            fmt_secs(scalar_secs),
            fmt_secs(simd_secs),
            scalar_gflops,
            simd_gflops,
            scalar_secs / simd_secs,
        );
        simd_objs.push(obj(vec![
            ("name", Json::Str(s.name.to_string())),
            ("m", Json::Num(m as f64)),
            ("d", Json::Num(d as f64)),
            ("n", Json::Num(n as f64)),
            ("gamma", Json::Num(g_both as f64)),
            ("density", Json::Num(rowmask.density())),
            ("madds", Json::Num(madds as f64)),
            ("vmm_scalar_secs", Json::Num(scalar_secs)),
            ("vmm_simd_secs", Json::Num(simd_secs)),
            ("scalar_gflops_per_core", Json::Num(scalar_gflops)),
            ("simd_gflops_per_core", Json::Num(simd_gflops)),
            ("simd_speedup", Json::Num(scalar_secs / simd_secs)),
            ("ulp_checked", Json::Bool(true)),
        ]));
    }
    let simd_total_speedup = simd_scalar_total / simd_simd_total.max(1e-12);
    println!(
        "simd vmm_dot totals ({}): scalar {} vs simd {} -> {:.2}x",
        simd_isa.label(),
        fmt_secs(simd_scalar_total),
        fmt_secs(simd_simd_total),
        simd_total_speedup
    );
    // the acceptance gate: a real vector unit must beat scalar on the
    // Fig 8a shapes (smoke shapes are too tiny to amortize and exempt)
    if simd_isa == dsg::sparse::simd::Isa::Avx2Fma && !smoke {
        assert!(
            simd_total_speedup > 1.0,
            "simd kernels slower than scalar ({simd_total_speedup:.2}x) on AVX2 hardware"
        );
    }

    // --- dispatch-overhead probe: many tiny dispatches, where the
    // per-call thread spawn dominates ---
    let (dm, dd, dn) = if smoke { (24, 64, 16) } else { (64, 128, 64) };
    let disp_reps = if smoke { 40 } else { 400 };
    let mut rng = Pcg32::seeded(77);
    let dx = Tensor::new(&[dm, dd], rng.normal_vec(dm * dd, 1.0));
    let dw = Tensor::new(&[dd, dn], rng.normal_vec(dd * dn, 1.0));
    let ((), spawn_total) = time_secs(|| {
        for _ in 0..disp_reps {
            let _ = matmul_spawn(&dx, &dw, threads);
        }
    });
    let ((), pool_total) = time_secs(|| {
        for _ in 0..disp_reps {
            let _ = parallel::matmul_parallel_with(&dx, &dw, threads);
        }
    });
    println!(
        "\ndispatch probe ({dm}x{dd}x{dn}, {disp_reps} calls, {threads} threads): \
         spawn {} pool {} -> {:.2}x",
        fmt_secs(spawn_total),
        fmt_secs(pool_total),
        spawn_total / pool_total
    );
    println!(
        "layer totals: spawn+dense {} vs pool+RowMask {} -> {:.2}x",
        fmt_secs(base_total),
        fmt_secs(new_total),
        base_total / new_total
    );

    let report = obj(vec![
        ("bench", Json::Str("engine_hotpath".to_string())),
        ("smoke", Json::Bool(smoke)),
        ("threads", Json::Num(threads as f64)),
        ("reps", Json::Num(reps as f64)),
        ("layers", Json::Arr(layer_objs)),
        ("compound_gamma05", Json::Arr(compound_objs)),
        ("simd_isa", Json::Str(simd_isa.label().to_string())),
        ("simd", Json::Arr(simd_objs)),
        (
            "simd_totals",
            obj(vec![
                ("vmm_scalar_secs", Json::Num(simd_scalar_total)),
                ("vmm_simd_secs", Json::Num(simd_simd_total)),
                ("simd_speedup", Json::Num(simd_total_speedup)),
            ]),
        ),
        (
            "compound_totals",
            obj(vec![
                ("output_sparse_madds", Json::Num(os_ops_total as f64)),
                ("compound_madds", Json::Num(comp_ops_total as f64)),
                ("ops_reduction", Json::Num(total_ops_x)),
            ]),
        ),
        (
            "dispatch_probe",
            obj(vec![
                ("m", Json::Num(dm as f64)),
                ("d", Json::Num(dd as f64)),
                ("n", Json::Num(dn as f64)),
                ("calls", Json::Num(disp_reps as f64)),
                ("spawn_total_secs", Json::Num(spawn_total)),
                ("pool_total_secs", Json::Num(pool_total)),
                ("pool_speedup", Json::Num(spawn_total / pool_total)),
            ]),
        ),
        (
            "totals",
            obj(vec![
                ("spawn_plus_dense_secs", Json::Num(base_total)),
                ("pool_plus_rowmask_secs", Json::Num(new_total)),
                ("speedup", Json::Num(base_total / new_total)),
            ]),
        ),
    ]);
    let out_path = std::env::var("DSG_BENCH_OUT").unwrap_or_else(|_| "BENCH_hotpath.json".into());
    std::fs::write(&out_path, report.to_string())?;
    println!("\nwrote {out_path}");
    println!("{}", report.to_string());
    println!(
        "engine_hotpath OK (all variants bit-identical, compound ops reduction {:.2}x)",
        total_ops_x
    );
    Ok(())
}
