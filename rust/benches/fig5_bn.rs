//! Fig 5(e): BN compatibility — no-BN single mask vs BN single mask vs
//! BN double mask (the paper's double-mask selection), on vgg8s.
//!
//! Expected: no-BN degrades fastest; double mask >= single mask with the
//! sparsity actually restored after BN.

use dsg::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    dsg::benchutil::header(
        "Fig 5(e)",
        "double-mask selection vs single mask vs no BN",
        "no-BN very sensitive; double-mask best (regularization effect)",
    );
    let rt = Runtime::cpu()?;
    let steps = dsg::benchutil::bench_steps();
    let gammas = [0.0f32, 0.5, 0.7, 0.9];
    for (label, variant) in [
        ("no-BN+1mask", "vgg8s_nobn"),
        ("BN+1mask", "vgg8s_single"),
        ("BN+2mask", "vgg8s"),
    ] {
        let mut series = Vec::new();
        for &g in &gammas {
            let (acc, _) = dsg::benchutil::train_at(&rt, variant, g, steps, 7)?;
            series.push((g, acc));
        }
        dsg::benchutil::print_series(label, &series);
    }
    Ok(())
}
