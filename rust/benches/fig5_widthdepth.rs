//! Fig 5(f): width vs depth under sparsity — the wide WRN-8-2 vs the
//! deeper-but-slimmer ResNet8.
//!
//! Expected: comparable at low/medium sparsity; the wide net holds up
//! better in the ultra-high-sparsity regime (pruning-error accumulation
//! over depth).

use dsg::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    dsg::benchutil::header(
        "Fig 5(f)",
        "network width vs depth under increasing sparsity",
        "deep slightly better at medium sparsity; wide more robust >75%",
    );
    let rt = Runtime::cpu()?;
    let steps = dsg::benchutil::bench_steps();
    let gammas = [0.0f32, 0.5, 0.75, 0.9];
    let mut finals = Vec::new();
    for (label, variant) in [("resnet8 (deep)", "resnet8"), ("wrn8_2 (wide)", "wrn8_2")] {
        let mut series = Vec::new();
        for &g in &gammas {
            let (acc, _) = dsg::benchutil::train_at(&rt, variant, g, steps, 7)?;
            series.push((g, acc));
        }
        dsg::benchutil::print_series(label, &series);
        finals.push(series);
    }
    let deep_drop = finals[0][0].1 - finals[0][3].1;
    let wide_drop = finals[1][0].1 - finals[1][3].1;
    println!(
        "\naccuracy drop 0->90%: deep {:.3} vs wide {:.3} (wide should degrade less)",
        deep_drop, wide_drop
    );
    Ok(())
}
