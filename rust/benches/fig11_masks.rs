//! Fig 11: selection-mask convergence.
//! (a) the per-sample mask stabilizes as training proceeds (L1 diff of
//!     the same batch's masks across training stages shrinks);
//! (b) masks differ strongly ACROSS samples even after training — which
//!     is why the paper keeps on-the-fly DRS at inference instead of
//!     caching masks.

use dsg::datasets;
use dsg::runtime::{HostTensor, Meta, Runtime};

fn probe_masks(
    rt: &Runtime,
    meta: &Meta,
    t: &dsg::coordinator::Trainer,
    xs: &[f32],
    gamma: f32,
) -> anyhow::Result<Vec<Vec<f32>>> {
    let exe = rt.load_artifact(meta, "probe")?;
    let mut inputs: Vec<HostTensor> = Vec::new();
    inputs.extend(t.state.params(meta).iter().cloned());
    inputs.extend(t.state.bn(meta).iter().cloned());
    inputs.extend(t.state.bn_state(meta).iter().cloned());
    inputs.extend(t.state.wps.iter().cloned());
    inputs.extend(t.state.rs.iter().cloned());
    let mut shape = vec![meta.batch];
    shape.extend_from_slice(&meta.input_shape);
    inputs.push(HostTensor::f32(&shape, xs.to_vec()));
    inputs.push(HostTensor::scalar_f32(gamma));
    let inputs = meta.filter_kept("probe", inputs);
    let outs = exe.run(&inputs)?;
    Ok(outs[1..].iter().map(|m| m.as_f32().unwrap().to_vec()).collect())
}

fn l1_diff(a: &[f32], b: &[f32]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs() as f64).sum()
}

fn main() -> anyhow::Result<()> {
    dsg::benchutil::header(
        "Fig 11",
        "selection-mask convergence over training / variance across samples",
        "per-sample masks converge; cross-sample masks stay very different",
    );
    let rt = Runtime::cpu()?;
    let dir = dsg::artifacts_dir();
    let meta = Meta::load(&dir, "lenet")?;
    let gamma = 0.7;
    let stage = dsg::benchutil::bench_steps() / 3;

    let mut cfg = dsg::config::RunConfig::preset_for_model("lenet");
    cfg.steps = stage;
    cfg.eval_every = 0;
    let data = datasets::fashion_like(1024, 5);
    let (train, test) = data.split(0.25);
    // fixed probe batch
    let (probe_x, _) = datasets::BatchIter::new(&test, meta.batch, 2).next_batch();

    let mut t = dsg::coordinator::Trainer::new(&rt, meta.clone(), 5)?;
    let mut prev = probe_masks(&rt, &meta, &t, &probe_x, gamma)?;
    println!(
        "\n(a) batch-avg L1 mask change per layer across training stages ({stage} steps each):"
    );
    println!("{:>7} {:>10} {:>10} {:>10} {:>10}", "stage", "conv1", "conv2", "fc1", "fc2");
    for s in 1..=4 {
        t.train(&cfg, &train, &test)?;
        let cur = probe_masks(&rt, &meta, &t, &probe_x, gamma)?;
        let diffs: Vec<f64> = prev
            .iter()
            .zip(&cur)
            .map(|(a, b)| l1_diff(a, b) / meta.batch as f64)
            .collect();
        println!(
            "{:>7} {:>10.1} {:>10.1} {:>10.1} {:>10.1}",
            s, diffs[0], diffs[1], diffs[2], diffs[3]
        );
        prev = cur;
    }
    println!("(values should shrink stage over stage)");

    // (b) cross-sample differences after training
    println!("\n(b) L1 diff of masks between ADJACENT SAMPLES after training:");
    let masks = probe_masks(&rt, &meta, &t, &probe_x, gamma)?;
    for (li, m) in masks.iter().enumerate() {
        let per = m.len() / meta.batch;
        let mut acc = 0.0;
        for b in 0..meta.batch - 1 {
            acc += l1_diff(&m[b * per..(b + 1) * per], &m[(b + 1) * per..(b + 2) * per]);
        }
        let avg = acc / (meta.batch - 1) as f64;
        let kept = m.iter().sum::<f32>() as f64 / meta.batch as f64;
        println!(
            "  layer {li}: avg adjacent-sample L1 {avg:.1} (kept/sample ~{kept:.0}) — large => masks are per-sample"
        );
    }
    println!("(this is why inference keeps on-the-fly DRS instead of caching masks)");
    Ok(())
}
