//! Fig 6: representational cost (memory footprint) for training and
//! inference across the five CNN benchmarks under ZVC at 50/80/90%
//! activation sparsity.

use dsg::costmodel::shapes::fig6_nets;
use dsg::memmodel;
use dsg::util::human_bytes;

fn main() {
    dsg::benchutil::header(
        "Fig 6",
        "memory footprint, training and inference, ZVC-compressed",
        "avg 1.7x (50%), 3.2x (80%), 4.2x (90%) training; acts up to 7.1x; infer <= 1.7x",
    );
    for &sp in &[0.5f64, 0.8, 0.9] {
        println!("\n--- activation sparsity {:.0}% ---", sp * 100.0);
        println!(
            "{:<10} {:>6} {:>11} {:>11} {:>11} {:>8} {:>7} {:>11} {:>11} {:>8}",
            "model", "batch", "tr-dense", "tr-dsg", "weights", "train-x", "act-x",
            "inf-dense", "inf-dsg", "infer-x"
        );
        let mut avg_train = 0.0;
        let mut saved: u64 = 0;
        let nets = fig6_nets();
        for net in &nets {
            let m = memmodel::memory(net, sp);
            avg_train += m.train_reduction();
            saved += m.train_dense() - m.train_dsg();
            println!(
                "{:<10} {:>6} {:>11} {:>11} {:>11} {:>7.2}x {:>6.2}x {:>11} {:>11} {:>7.2}x",
                net.name,
                net.batch,
                human_bytes(m.train_dense()),
                human_bytes(m.train_dsg()),
                human_bytes(m.weights),
                m.train_reduction(),
                m.act_reduction(),
                human_bytes(m.infer_dense()),
                human_bytes(m.infer_dsg()),
                m.infer_reduction()
            );
        }
        println!(
            "average train reduction {:.2}x, total saved {} (paper: 1.7x/2.72GB @50, 3.2x/4.51GB @80, 4.2x/5.04GB @90)",
            avg_train / nets.len() as f64,
            human_bytes(saved / nets.len() as u64)
        );
    }
    // mask overhead + the ResNet152 inference caveat (§3.3)
    println!("\nmask overhead (vs dense train footprint, paper '<2%'):");
    for net in fig6_nets() {
        let m = memmodel::memory(&net, 0.8);
        println!("  {:<10} {:.2}%", net.name, 100.0 * m.mask_frac());
    }
}
