//! Fig 6: representational cost (memory footprint) under ZVC.
//!
//! Two sections since PR 4:
//!
//! 1. MEASURED — native training runs with the tape stored dense vs
//!    ZVC-compressed ([`TapeStorage::Zvc`]), across gamma.  Peak tape
//!    bytes come from the engine's [`dsg::metrics::MemoryMeter`], i.e.
//!    they are what the backward pass actually held, not a model; the
//!    two tapes are asserted bit-identical (losses match to the bit) and
//!    the dense run's peak is asserted equal to the ZVC run's
//!    dense-equivalent accounting.  At gamma 0.5 the measured ZVC/dense
//!    reduction must clear 1.5x on the default topology (>1x in smoke).
//! 2. ANALYTIC — the paper's five CNN benchmarks under `memmodel` at
//!    50/80/90% activation sparsity (the original Fig 6 table).
//!
//! Writes `BENCH_memory.json` (override with `DSG_BENCH_OUT`).
//! `DSG_FIG6_SMOKE=1` shrinks the measured topology for CI.
//!
//! Accounting note (resolved): a keep-all mask (gamma 0 / dense mode)
//! used to be materialized as m*n u32 indices, inflating the measured
//! gamma-0 baseline on both sides of the ratio.  `RowMask` now stores
//! the full selection implicitly (one shared 0..n row), so the gamma-0
//! mask term is O(n) and the measured baseline is honest.

use dsg::coordinator::NativeTrainer;
use dsg::costmodel::shapes::fig6_nets;
use dsg::memmodel;
use dsg::native::train::TapeStorage;
use dsg::native::zoo::{self, ModelSpec};
use dsg::runtime::{Meta, Unit};
use dsg::util::human_bytes;
use dsg::util::json::{obj, Json};
use dsg::util::Pcg32;

/// The default measured topology: vgg8s, conv-dominated like the paper's
/// benchmarks.  Smoke mode swaps in a tiny conv net with the same
/// structure (conv -> conv -> pool -> dense -> classifier).
fn measured_spec(smoke: bool) -> ModelSpec {
    if !smoke {
        return zoo::spec_for("vgg8s").expect("vgg8s in zoo");
    }
    ModelSpec {
        name: "fig6_smoke".into(),
        base_model: "fig6_smoke".into(),
        input_shape: vec![2, 12, 12],
        classes: 4,
        batch: 8,
        units: vec![
            Unit::Conv { c_in: 2, c_out: 12, ksize: 3, stride: 1, pad: 1 },
            Unit::Conv { c_in: 12, c_out: 12, ksize: 3, stride: 1, pad: 1 },
            Unit::MaxPool { size: 2 },
            Unit::Flatten,
            Unit::Dense { d_in: 12 * 6 * 6, d_out: 32 },
            Unit::Classifier { d_in: 32, d_out: 4 },
        ],
        strategy: "drs".into(),
        eps: 0.5,
        double_mask: true,
        use_bn: true,
    }
}

fn batch_for(meta: &Meta, seed: u64) -> (Vec<f32>, Vec<i32>) {
    let mut rng = Pcg32::seeded(seed);
    let x = rng.normal_vec(meta.batch * meta.input_elems(), 1.0);
    let y = (0..meta.batch).map(|_| rng.below(meta.classes as u32) as i32).collect();
    (x, y)
}

/// Train `steps` steps at constant `gamma` under `tape`; returns
/// (per-step loss bits, peak tape bytes, dense-equivalent peak,
/// act-only reduction, measured act sparsity, per-record rows).
fn run_measured(
    meta: &Meta,
    tape: TapeStorage,
    gamma: f32,
    steps: usize,
) -> anyhow::Result<(Vec<u32>, u64, u64, f64, f64, Vec<Json>)> {
    let mut t = NativeTrainer::new(meta.clone(), 7)?.with_tape(tape);
    let mut losses = Vec::with_capacity(steps);
    for s in 0..steps {
        let (x, y) = batch_for(meta, 100 + s as u64);
        let out = t.step(&x, &y, gamma, 0.05)?;
        losses.push(out.loss.to_bits());
    }
    let mem = t.tape_memory();
    let rows = mem
        .allocs()
        .iter()
        .map(|a| {
            obj(vec![
                ("unit", Json::Num(a.unit as f64)),
                ("part", Json::Str(a.part.to_string())),
                ("elems", Json::Num(a.elems as f64)),
                ("sparsity", Json::Num(a.sparsity())),
                ("dense_bytes", Json::Num(a.dense_bytes as f64)),
                ("stored_bytes", Json::Num(a.stored_bytes as f64)),
            ])
        })
        .collect();
    Ok((
        losses,
        mem.peak(),
        mem.dense_peak(),
        mem.act_reduction(),
        mem.act_sparsity(),
        rows,
    ))
}

fn main() -> anyhow::Result<()> {
    dsg::benchutil::header(
        "Fig 6",
        "memory footprint: MEASURED ZVC training tape + analytic model",
        "avg 1.7x (50%), 3.2x (80%), 4.2x (90%) training; acts up to 7.1x; infer <= 1.7x",
    );
    let smoke = std::env::var("DSG_FIG6_SMOKE").is_ok();
    let spec = measured_spec(smoke);
    let meta = zoo::synth_meta(&spec)?;
    let steps = 2;
    println!(
        "\n=== measured: {} (batch {}, {} steps/config{}) ===",
        meta.name,
        meta.batch,
        steps,
        if smoke { ", smoke" } else { "" }
    );
    println!(
        "{:>6} {:>12} {:>12} {:>8} {:>8} {:>10}",
        "gamma", "dense-peak", "zvc-peak", "tape-x", "act-x", "act-sprs"
    );
    let mut gamma_objs = Vec::new();
    let mut ratio_at = std::collections::BTreeMap::new();
    for &gamma in &[0.0f32, 0.5, 0.8] {
        let (dl, dense_peak, dense_dense, _, _, _) =
            run_measured(&meta, TapeStorage::Dense, gamma, steps)?;
        let (zl, zvc_peak, zvc_dense, act_x, act_s, rows) =
            run_measured(&meta, TapeStorage::Zvc, gamma, steps)?;
        // ZVC is lossless: the two tapes must train IDENTICALLY
        assert_eq!(dl, zl, "gamma {gamma}: zvc tape diverged from dense tape");
        // and the ZVC run's dense-equivalent accounting must equal what
        // the dense run actually peaked at (same records, same shapes)
        assert_eq!(
            dense_peak, zvc_dense,
            "gamma {gamma}: dense-equivalent accounting disagrees"
        );
        assert_eq!(dense_peak, dense_dense, "dense tape must store at dense cost");
        let ratio = dense_peak as f64 / zvc_peak.max(1) as f64;
        ratio_at.insert((gamma * 100.0) as u32, ratio);
        println!(
            "{:>6.2} {:>12} {:>12} {:>7.2}x {:>7.2}x {:>9.2}%",
            gamma,
            human_bytes(dense_peak),
            human_bytes(zvc_peak),
            ratio,
            act_x,
            100.0 * act_s
        );
        gamma_objs.push(obj(vec![
            ("gamma", Json::Num(gamma as f64)),
            ("dense_peak_bytes", Json::Num(dense_peak as f64)),
            ("zvc_peak_bytes", Json::Num(zvc_peak as f64)),
            ("reduction", Json::Num(ratio)),
            ("act_reduction", Json::Num(act_x)),
            ("act_sparsity", Json::Num(act_s)),
            ("records", Json::Arr(rows)),
        ]));
    }
    let r0 = ratio_at[&0];
    let r50 = ratio_at[&50];
    let r80 = ratio_at[&80];
    println!(
        "measured tape reduction: {r0:.2}x @ gamma 0, {r50:.2}x @ 0.5, {r80:.2}x @ 0.8"
    );
    // the acceptance gates: real savings at the paper's operating point,
    // growing with gamma exactly as the analytic model predicts
    if smoke {
        assert!(r50 > 1.0, "smoke: ZVC must beat dense at gamma 0.5 (got {r50:.3})");
    } else {
        assert!(r50 >= 1.5, "ZVC/dense must clear 1.5x at gamma 0.5 (got {r50:.3})");
    }
    assert!(
        r80 > r50 && r50 > r0,
        "reduction must grow with gamma ({r0:.3} / {r50:.3} / {r80:.3})"
    );

    // ---------------- analytic section (paper shapes) ----------------
    let mut analytic_objs = Vec::new();
    for &sp in &[0.5f64, 0.8, 0.9] {
        println!("\n--- analytic, activation sparsity {:.0}% ---", sp * 100.0);
        println!(
            "{:<10} {:>6} {:>11} {:>11} {:>11} {:>8} {:>7} {:>11} {:>11} {:>8}",
            "model", "batch", "tr-dense", "tr-dsg", "weights", "train-x", "act-x",
            "inf-dense", "inf-dsg", "infer-x"
        );
        let mut avg_train = 0.0;
        let mut saved: u64 = 0;
        let nets = fig6_nets();
        for net in &nets {
            let m = memmodel::memory(net, sp);
            avg_train += m.train_reduction();
            saved += m.train_dense() - m.train_dsg();
            println!(
                "{:<10} {:>6} {:>11} {:>11} {:>11} {:>7.2}x {:>6.2}x {:>11} {:>11} {:>7.2}x",
                net.name,
                net.batch,
                human_bytes(m.train_dense()),
                human_bytes(m.train_dsg()),
                human_bytes(m.weights),
                m.train_reduction(),
                m.act_reduction(),
                human_bytes(m.infer_dense()),
                human_bytes(m.infer_dsg()),
                m.infer_reduction()
            );
        }
        let avg = avg_train / nets.len() as f64;
        println!(
            "average train reduction {:.2}x, total saved {} (paper: 1.7x/2.72GB @50, 3.2x/4.51GB @80, 4.2x/5.04GB @90)",
            avg,
            human_bytes(saved / nets.len() as u64)
        );
        analytic_objs.push(obj(vec![
            ("sparsity", Json::Num(sp)),
            ("avg_train_reduction", Json::Num(avg)),
        ]));
    }
    // mask overhead + the ResNet152 inference caveat (§3.3)
    println!("\nmask overhead (vs dense train footprint, paper '<2%'):");
    for net in fig6_nets() {
        let m = memmodel::memory(&net, 0.8);
        println!("  {:<10} {:.2}%", net.name, 100.0 * m.mask_frac());
    }

    let report = obj(vec![
        ("bench", Json::Str("fig6_memory".to_string())),
        ("smoke", Json::Bool(smoke)),
        (
            "measured",
            obj(vec![
                ("model", Json::Str(meta.name.clone())),
                ("batch", Json::Num(meta.batch as f64)),
                ("steps", Json::Num(steps as f64)),
                ("gammas", Json::Arr(gamma_objs)),
            ]),
        ),
        ("analytic", Json::Arr(analytic_objs)),
    ]);
    let out_path = std::env::var("DSG_BENCH_OUT").unwrap_or_else(|_| "BENCH_memory.json".into());
    std::fs::write(&out_path, report.to_string())?;
    println!("\nwrote {out_path}");
    println!("fig6_memory OK (zvc tape bit-identical, measured reduction gates passed)");
    Ok(())
}
