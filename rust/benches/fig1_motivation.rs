//! Fig 1 motivation panels, reproduced quantitatively:
//! (c) neuronal activations dominate representational cost at large batch;
//! (e) BN destroys activation sparsity (measured through the artifacts);
//! (f) representational redundancy: most activations are near zero.

use dsg::costmodel::shapes;
use dsg::runtime::Runtime;
use dsg::util::human_bytes;

fn main() -> anyhow::Result<()> {
    dsg::benchutil::header(
        "Fig 1",
        "motivation: activation-dominated memory + near-zero redundancy",
        "(c) acts >> weights at large batch; (f) >80% of activations near zero",
    );

    // (c) weights vs activations as batch grows (VGG8 shapes)
    println!("\n(c) VGG8 memory split vs mini-batch size:");
    println!("{:>8} {:>12} {:>12} {:>8}", "batch", "weights", "activations", "act %");
    for batch in [1usize, 8, 32, 128, 256] {
        let net = shapes::vgg8(batch);
        let w = net.total_weights() * 4;
        let a = net.total_acts_per_sample() * batch as u64 * 4;
        println!(
            "{:>8} {:>12} {:>12} {:>7.1}%",
            batch,
            human_bytes(w),
            human_bytes(a),
            100.0 * a as f64 / (a + w) as f64
        );
    }

    // (f) activation magnitude distribution on a trained model
    let rt = Runtime::cpu()?;
    let steps = dsg::benchutil::bench_steps().min(150);
    let (_, t) = dsg::benchutil::train_at(&rt, "mlp_dense", 0.0, steps, 3)?;
    let data = dsg::datasets::fashion_like(t.meta.batch, 9);
    let (xs, _) = dsg::datasets::BatchIter::new(&data, t.meta.batch, 1).next_batch();
    let logits = t.forward(&xs, 0.0)?;
    // logits are post-net; for the motivation panel use the pre-softmax
    // distribution + the headline claim on ReLU nets: measure fraction of
    // small activations via the dense mlp's hidden masks through probe on
    // the DSG variant at gamma=0 (masks all ones, so use logits stats).
    let max = logits.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
    let near_zero = logits.iter().filter(|v| v.abs() < 0.1 * max).count();
    println!(
        "\n(f) trained-model output activations: {:.1}% below 10% of max |a| (batch {})",
        100.0 * near_zero as f64 / logits.len() as f64,
        t.meta.batch
    );

    // ReLU hidden-layer sparsity, measured directly on the rust engine:
    let mut rng = dsg::Pcg32::seeded(4);
    let x = dsg::Tensor::new(&[64, 256], rng.normal_vec(64 * 256, 1.0));
    let w = dsg::Tensor::new(&[256, 256], rng.normal_vec(256 * 256, (2.0 / 256.0f32).sqrt()));
    let mut y = dsg::tensor::ops::matmul_blocked(&x, &w);
    dsg::tensor::ops::relu_inplace(&mut y);
    let zeros = y.zero_fraction();
    let small = y
        .data()
        .iter()
        .filter(|&&v| v.abs() < 0.25)
        .count() as f64
        / y.len() as f64;
    println!(
        "    ReLU hidden layer: {:.1}% exactly zero, {:.1}% below 0.25 (paper: >80% near zero)",
        zeros * 100.0,
        small * 100.0
    );
    Ok(())
}
