//! Data-parallel training scaling: the SAME run at `--shards` 1/2/4/8,
//! asserted bit-identical (losses, digest), with epoch wall-clock per
//! shard count and the ZVC gradient-exchange wire accounting.
//!
//! The batch is 8 rows = 8 one-row micro-leaves, so every leaf's
//! gradient carries that single row's DSG mask zeros — the regime the
//! paper's gradient-exchange compression claim is about.  The bench
//! FAILS if the dense/wire ratio drops under 1.5x at gamma 0.5, or if
//! any shard count moves a bit.
//!
//! Writes machine-readable `BENCH_train.json` (override the path with
//! `DSG_BENCH_OUT`) — uploaded by CI as the training perf artifact.
//!
//!     cargo bench --bench train_scaling
//!     DSG_TRAIN_SMOKE=1 cargo bench --bench train_scaling   # CI: tiny
//!     DSG_TRAIN_STEPS=200 cargo bench --bench train_scaling

use dsg::config::{GammaSchedule, RunConfig};
use dsg::native::train::TapeStorage;
use dsg::native::zoo::{self, ModelSpec};
use dsg::train::ParallelTrainer;
use dsg::util::json::{obj, Json};
use std::time::Instant;

struct Point {
    shards: usize,
    wall_secs: f64,
    epoch_secs: f64,
    digest: u64,
    final_loss: f32,
    retries: u64,
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::var("DSG_TRAIN_SMOKE").is_ok();
    let steps = std::env::var("DSG_TRAIN_STEPS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .unwrap_or(if smoke { 10 } else { 60 });
    let width = if smoke { 32 } else { 128 };
    let batch = 8; // = LEAVES one-row micro-leaves
    let spec = ModelSpec::custom_mlp("scale_mlp", &[784, width], 10, batch);

    let mut cfg = RunConfig::preset_for_model("mlp");
    cfg.steps = steps;
    cfg.eval_every = 0;
    cfg.train_size = if smoke { 64 } else { 512 };
    cfg.test_size = 32;
    cfg.gamma = GammaSchedule::Constant(0.5);
    let (train, test) = dsg::benchutil::data_for(&cfg);
    let batches_per_epoch = (cfg.train_size + batch - 1) / batch;

    println!("train_scaling: {steps} steps, batch {batch}, hidden {width}, gamma 0.5");
    println!(
        "{:>7} {:>10} {:>12} {:>12} {:>18}",
        "shards", "wall (s)", "epoch (s)", "final loss", "digest"
    );
    let mut points: Vec<Point> = Vec::new();
    let mut wire = None;
    for shards in [1usize, 2, 4, 8] {
        let meta = zoo::synth_meta(&spec)?;
        let mut t = ParallelTrainer::new(meta, 7, shards)?.with_tape(TapeStorage::Zvc);
        let t0 = Instant::now();
        t.train(&cfg, &train, &test)?;
        let wall_secs = t0.elapsed().as_secs_f64();
        let epoch_secs = wall_secs / steps as f64 * batches_per_epoch as f64;
        let digest = t.state.digest();
        let final_loss = t.history.steps.last().map(|s| s.loss).unwrap_or(f32::NAN);
        let retries: u64 = t.shard_stats().iter().map(|s| s.retries).sum();
        println!(
            "{:>7} {:>10.3} {:>12.3} {:>12.4} {:>18}",
            shards,
            wall_secs,
            epoch_secs,
            final_loss,
            format!("{digest:016x}")
        );
        wire = Some(t.wire_stats());
        points.push(Point { shards, wall_secs, epoch_secs, digest, final_loss, retries });
    }

    // the crown-jewel assertion: the shard count never moves a bit
    let d0 = points[0].digest;
    for p in &points {
        anyhow::ensure!(
            p.digest == d0,
            "digest diverged at {} shards: {:016x} vs {:016x}",
            p.shards,
            p.digest,
            d0
        );
        anyhow::ensure!(
            p.final_loss.to_bits() == points[0].final_loss.to_bits(),
            "final loss diverged at {} shards",
            p.shards
        );
    }

    // gradient-exchange accounting from the last (8-shard) run
    let w = wire.expect("at least one run");
    let ratio = w.ratio();
    println!(
        "gradient exchange: {} wire vs {} dense -> {ratio:.2}x (frames {} bytes)",
        w.grad_wire_bytes, w.grad_dense_bytes, w.frame_bytes
    );
    anyhow::ensure!(
        ratio >= 1.5,
        "ZVC gradient exchange only {ratio:.2}x at gamma 0.5 (want >= 1.5x)"
    );

    let report = obj(vec![
        ("bench", Json::Str("train_scaling".into())),
        ("smoke", Json::Bool(smoke)),
        ("steps", Json::Num(steps as f64)),
        ("batch", Json::Num(batch as f64)),
        ("gamma", Json::Num(0.5)),
        ("bit_identical", Json::Bool(true)),
        (
            "scaling",
            Json::Arr(
                points
                    .iter()
                    .map(|p| {
                        obj(vec![
                            ("shards", Json::Num(p.shards as f64)),
                            ("wall_secs", Json::Num(p.wall_secs)),
                            ("epoch_secs", Json::Num(p.epoch_secs)),
                            ("final_loss", Json::Num(p.final_loss as f64)),
                            ("retries", Json::Num(p.retries as f64)),
                            ("digest", Json::Str(format!("{:016x}", p.digest))),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "gradient_exchange",
            obj(vec![
                ("frame_bytes", Json::Num(w.frame_bytes as f64)),
                ("grad_wire_bytes", Json::Num(w.grad_wire_bytes as f64)),
                ("grad_dense_bytes", Json::Num(w.grad_dense_bytes as f64)),
                ("ratio", Json::Num(ratio)),
            ]),
        ),
    ]);
    let out_path = std::env::var("DSG_BENCH_OUT").unwrap_or_else(|_| "BENCH_train.json".into());
    std::fs::write(&out_path, report.to_string())?;
    println!("\nwrote {out_path}");
    println!("train_scaling OK (all shard counts bit-identical, exchange >= 1.5x)");
    Ok(())
}
